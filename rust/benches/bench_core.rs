//! Micro-benchmarks of the framework hot paths (the §Perf working set):
//! aggregation vector math, sharing serialization, compression codecs,
//! top-k selection, secure-mask expansion, wire framing, in-proc
//! transport, graph generation, and the PJRT train/agg steps.
//!
//! Run: `cargo bench --bench bench_core` (artifact-dependent benches skip
//! when artifacts are missing).

use decentralize_rs::bench::{black_box, run};
use decentralize_rs::communication::{decode_envelope, encode_envelope, Envelope, MsgKind};
use decentralize_rs::compression::{encode_indices_best, FloatCodec, Fp16, Qsgd, RawF32};
use decentralize_rs::graph;
use decentralize_rs::model::ParamVec;
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::secure;
use decentralize_rs::sharing::{self, Received, Sharing};

const P: usize = 49_866; // mlp parameter count (the real model size)

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn main() {
    println!("== bench_core: framework hot paths (P = {P}) ==");

    // --- ParamVec math (aggregation inner loop) ---
    {
        let mut acc = ParamVec::from_vec(rand_vec(P, 1));
        let other = ParamVec::from_vec(rand_vec(P, 2));
        run("paramvec/axpy", 300, || acc.axpy(0.3, black_box(&other)))
            .print_throughput(P as f64, "elem");
        run("paramvec/topk_threshold_10pct", 500, || {
            black_box(other.topk_threshold(P / 10));
        });
        run("paramvec/topk_extract_10pct", 500, || {
            black_box(other.topk(P / 10));
        });
    }

    // --- Sharing strategies: outgoing + aggregate ---
    {
        let model = ParamVec::from_vec(rand_vec(P, 3));
        let mut full = sharing::from_spec("full", P, 0).unwrap();
        let payload = full.outgoing(&model, 0).unwrap();
        run("sharing/full/outgoing", 300, || {
            black_box(full.outgoing(&model, 0).unwrap());
        });
        let mut model2 = model.clone();
        run("sharing/full/aggregate_deg5", 300, || {
            let received: Vec<Received> = (0..5)
                .map(|s| Received { src: s, weight: 1.0 / 6.0, payload: &payload })
                .collect();
            full.aggregate(&mut model2, 1.0 - 5.0 / 6.0, &received).unwrap();
        });

        let mut choco = sharing::from_spec("choco:0.1:0.5", P, 0).unwrap();
        choco.set_init(&model);
        run("sharing/choco/outgoing_10pct", 300, || {
            black_box(choco.outgoing(&model, 0).unwrap());
        });

        let mut topk = sharing::from_spec("topk:0.1", P, 0).unwrap();
        run("sharing/topk/outgoing_10pct", 300, || {
            black_box(topk.outgoing(&model, 0).unwrap());
        });
    }

    // --- Compression codecs ---
    {
        let vals = rand_vec(P, 4);
        run("codec/raw_f32/encode", 200, || {
            black_box(RawF32.encode(&vals));
        })
        .print_throughput(P as f64, "elem");
        run("codec/fp16/encode", 200, || {
            black_box(Fp16.encode(&vals));
        })
        .print_throughput(P as f64, "elem");
        let q = Qsgd::new(128, 1);
        let qenc = q.encode(&vals);
        run("codec/qsgd/encode", 200, || {
            black_box(q.encode(&vals));
        })
        .print_throughput(P as f64, "elem");
        run("codec/qsgd/decode", 200, || {
            black_box(q.decode(&qenc, P).unwrap());
        });
        let idx: Vec<u32> = (0..P as u32).step_by(10).collect();
        run("codec/index_best/encode_10pct", 200, || {
            black_box(encode_indices_best(&idx, P));
        });
    }

    // --- Secure aggregation mask expansion ---
    {
        let masker = secure::Masker::new(0, 1, 4.0);
        run("secure/mask_deg5", 300, || {
            black_box(masker.mask_for(1, 0, &[0, 2, 3, 4, 5], 6.0, P));
        })
        .print_throughput(P as f64, "elem");
        let seed = [9u8; 16];
        run("secure/aes_ctr_expand", 300, || {
            black_box(secure::expand_mask(&seed, P, 1.0));
        })
        .print_throughput(P as f64, "elem");
    }

    // --- Wire framing + transport ---
    {
        let env = Envelope {
            src: 0,
            dst: 1,
            round: 3,
            kind: MsgKind::Model,
            sent_at_s: 0.0,
            trace: 0,
            payload: vec![7u8; P * 4].into(),
        };
        let bytes = encode_envelope(&env);
        run("wire/encode_200KB", 200, || {
            black_box(encode_envelope(&env));
        });
        run("wire/decode_200KB", 200, || {
            black_box(decode_envelope(&bytes).unwrap());
        });

        use decentralize_rs::communication::inproc::InprocHub;
        use decentralize_rs::communication::Transport;
        let hub = InprocHub::new(2);
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        run("transport/inproc_roundtrip_200KB", 300, || {
            a.send(env.clone()).unwrap();
            black_box(b.recv().unwrap());
        });
    }

    // --- Graph generation (dynamic-topology path: one graph per round) ---
    {
        let mut rng = Xoshiro256pp::new(5);
        run("graph/random_regular_256_d5", 400, || {
            black_box(graph::random_regular(256, 5, &mut rng).unwrap());
        });
        let mut rng2 = Xoshiro256pp::new(6);
        run("graph/mh_weights_256_d5", 200, || {
            let g = graph::random_regular(256, 5, &mut rng2).unwrap();
            black_box(graph::metropolis_hastings(&g));
        });
    }

    // --- PJRT engine (needs artifacts) ---
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        use decentralize_rs::runtime::EngineHandle;
        let engine = EngineHandle::start(&art, &["mlp"]).unwrap();
        let meta = engine.manifest().model("mlp").unwrap().clone();
        let params = meta.load_init().unwrap();
        let (h, w, c) = meta.input_shape;
        let x = rand_vec(meta.train_batch * h * w * c, 7);
        let y: Vec<i32> = (0..meta.train_batch as i32).collect();
        run("engine/train_step_mlp_b8", 1500, || {
            black_box(
                engine
                    .train_step("mlp", params.clone(), x.clone(), y.clone(), 0.05)
                    .unwrap(),
            );
        });
        let ex = rand_vec(meta.eval_batch * h * w * c, 8);
        let ey: Vec<i32> = (0..meta.eval_batch as i32).map(|i| i % 10).collect();
        run("engine/eval_batch_mlp_b32", 1500, || {
            black_box(
                engine
                    .eval_batch("mlp", params.clone(), ex.clone(), ey.clone())
                    .unwrap(),
            );
        });
        let stack = rand_vec(meta.agg_k * meta.param_count, 9);
        let weights = vec![1.0 / meta.agg_k as f32; meta.agg_k];
        run("engine/pallas_aggregate_k16", 1500, || {
            black_box(engine.aggregate("mlp", stack.clone(), weights.clone()).unwrap());
        });
        // Rust-native aggregation of the same k models (ablation vs the
        // Pallas artifact; the coordinator uses whichever wins — see
        // DESIGN.md §Perf).
        let models: Vec<ParamVec> = (0..meta.agg_k)
            .map(|i| {
                ParamVec::from_vec(
                    stack[i * meta.param_count..(i + 1) * meta.param_count].to_vec(),
                )
            })
            .collect();
        run("native/aggregate_k16", 300, || {
            let mut acc = ParamVec::zeros(meta.param_count);
            for m in &models {
                acc.axpy(1.0 / meta.agg_k as f32, m);
            }
            black_box(acc);
        });
        engine.shutdown();
    } else {
        println!("(artifacts missing: engine benches skipped — run `make artifacts`)");
    }
    println!("== bench_core done ==");
}
