//! Fig 8 (beyond-the-paper) bench: asynchronous gossip vs synchronous
//! D-PSGD under compute stragglers — the headline claim of the async
//! subsystem is that dropping the per-round completeness barrier turns
//! straggler-paced rounds into deadline-paced ones, reaching the same
//! accuracy in strictly less *virtual* time. Also sweeps staleness
//! policies, demonstrates worker-count determinism on a shared
//! `prepare()`, and shows a mid-round crash completing on timeouts.
//! Skips cleanly without artifacts.

mod fig_common;

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::{prepare, RunHooks, RunResult, Runner, SchedulerRunner};
use decentralize_rs::scenario::Scenario;
use fig_common::{bench_config, engine_or_skip, run_variant};

/// Earliest mean emulated time at which the run's accuracy reached
/// `target` (virtual time-to-accuracy; None if it never did).
fn time_to_accuracy(r: &RunResult, target: f64) -> Option<f64> {
    r.series
        .iter()
        .find(|p| p.test_acc.mean >= target)
        .map(|p| p.emu_time_s.mean)
}

/// Smallest seed whose straggler draw actually produces a straggler, so
/// the sweep never silently degenerates into a uniform fleet.
fn seed_with_stragglers(cfg: &ExperimentConfig) -> u64 {
    (1..1000u64)
        .find(|&seed| {
            Scenario::from_specs(
                &cfg.step_time,
                &cfg.link_model,
                &cfg.churn_trace,
                &cfg.byzantine,
                None,
                cfg.nodes,
                cfg.rounds,
                seed,
            )
            .map(|s| !s.compute.is_uniform())
            .unwrap_or(false)
        })
        .expect("a straggler-bearing seed under 1000")
}

fn main() {
    println!("== fig8: asynchronous gossip (deadlines + staleness) ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    // Shared base: 12 nodes, 1/10 of the fleet 10x slower.
    let mut sync_cfg = bench_config("fig8/sync_stragglers");
    sync_cfg.rounds = 12;
    sync_cfg.eval_every = 2;
    sync_cfg.step_time = "stragglers:0.1:10".into();
    sync_cfg.seed = seed_with_stragglers(&sync_cfg);

    println!("-- sync vs async under stragglers:0.1:10 (12 nodes, regular:5) --");
    let r_sync = run_variant(&sync_cfg, &engine);
    let mut async_cfg = sync_cfg.clone();
    async_cfg.name = "fig8/async_factor2".into();
    async_cfg.mode = "async_dl".into();
    async_cfg.deadline = "factor:2".into();
    async_cfg.staleness = "linear:10".into();
    let r_async = run_variant(&async_cfg, &engine);

    // Virtual time-to-accuracy at the sync run's near-final accuracy.
    let target = r_sync.final_accuracy() * 0.95;
    let t_sync = time_to_accuracy(&r_sync, target);
    let t_async = time_to_accuracy(&r_async, target);
    match (t_sync, t_async) {
        (Some(ts), Some(ta)) => {
            println!(
                "time to acc {:.3}: sync {:>8.3}s vs async {:>8.3}s ({:.2}x) => {}",
                target,
                ts,
                ta,
                ts / ta,
                if ta < ts { "ASYNC WINS" } else { "async did not win" }
            );
        }
        _ => println!(
            "time to acc {target:.3}: sync {t_sync:?} async {t_async:?} (target unreached)"
        ),
    }

    // Deadline / staleness sweep at the same scale.
    println!("-- deadline x staleness sweep --");
    for (deadline, staleness) in [
        ("factor:1.5", "none"),
        ("factor:2", "linear:10"),
        ("factor:3", "poly:0.5"),
        ("p90", "linear:10"),
    ] {
        let mut cfg = async_cfg.clone();
        cfg.name = format!("fig8/async_{}_{}", deadline.replace(':', "_"), staleness.replace(':', "_"));
        cfg.deadline = deadline.into();
        cfg.staleness = staleness.into();
        let r = run_variant(&cfg, &engine);
        let last = r.logs.iter().filter_map(|l| l.records.last()).collect::<Vec<_>>();
        let late: u64 = last.iter().map(|r| r.late_msgs).sum();
        let stale: f64 =
            last.iter().map(|r| r.mean_staleness_s).sum::<f64>() / last.len().max(1) as f64;
        println!(
            "  deadline {deadline:<10} staleness {staleness:<10} late msgs {late:>4}  mean staleness {stale:>7.4}s"
        );
    }

    // Determinism: one prepare(), three worker counts, identical logs.
    println!("-- worker-count determinism (shared prepare) --");
    let setup = prepare(&async_cfg, &engine).expect("prepare");
    let mut runs = Vec::new();
    for workers in [1usize, 4, 8] {
        let mut logs = SchedulerRunner { workers }
            .run(&async_cfg, &engine, &setup, &RunHooks::default())
            .expect("async run")
            .logs;
        logs.sort_by_key(|l| l.node);
        runs.push(logs);
    }
    let identical = runs[1..].iter().all(|other| {
        runs[0].iter().zip(other.iter()).all(|(a, b)| {
            a.records.len() == b.records.len()
                && a.records.iter().zip(b.records.iter()).all(|(x, y)| {
                    x.test_acc == y.test_acc
                        && x.emu_time_s == y.emu_time_s
                        && x.bytes_sent == y.bytes_sent
                        && x.mean_staleness_s == y.mean_staleness_s
                })
        })
    });
    println!(
        "  --workers 1/4/8 => {}",
        if identical { "BIT-IDENTICAL" } else { "MISMATCH (bug!)" }
    );

    // Crash churn: fixed windows make the virtual span machine-
    // independent; crashes land mid-run and neighbors time out.
    println!("-- mid-round crashes (crashes:0.25:2.0, fixed 0.4s windows) --");
    let mut crash_cfg = async_cfg.clone();
    crash_cfg.name = "fig8/async_crashes".into();
    crash_cfg.deadline = "fixed:0.4".into();
    crash_cfg.churn_trace = "crashes:0.25:2.0".into();
    let r_crash = run_variant(&crash_cfg, &engine);
    let full_len = r_crash.logs.iter().map(|l| l.records.len()).max().unwrap();
    let full = r_crash.logs.iter().filter(|l| l.records.len() == full_len).count();
    println!(
        "  run completed: {} of {} nodes logged the full experiment (rest crashed)",
        full,
        crash_cfg.nodes
    );
    println!("== fig8 done ==");
}
