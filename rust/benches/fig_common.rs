//! Shared plumbing for the per-figure end-to-end benches: each bench runs
//! the figure's experiment variants at a reduced scale and prints the
//! paper-style rows plus wall-clock per variant. Skips cleanly when
//! artifacts are missing so `cargo bench` always succeeds.

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::{run_experiment, RunResult};
use decentralize_rs::runtime::EngineHandle;

/// Reduced-scale base config used by all figure benches (calibrated task
/// difficulty; see EXPERIMENTS.md).
pub fn bench_config(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = name.into();
    cfg.nodes = 12;
    cfg.rounds = 12;
    cfg.eval_every = 6;
    cfg.train_total = 768;
    cfg.test_total = 128;
    cfg.noise = 2.2;
    cfg.lr = 0.03;
    cfg.local_steps = 1;
    cfg
}

pub fn engine_or_skip(models: &[&str]) -> Option<EngineHandle> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — run `make artifacts`; bench skipped)");
        return None;
    }
    match EngineHandle::start(&dir, models) {
        Ok(engine) => Some(engine),
        Err(e) => {
            println!("(PJRT engine unavailable: {e:#}; bench skipped)");
            None
        }
    }
}

pub fn run_variant(cfg: &ExperimentConfig, engine: &EngineHandle) -> RunResult {
    let r = run_experiment(cfg, engine).expect("experiment");
    println!(
        "bench {:<28} acc {:>7.4}  bytes/node {:>12.0}  emu {:>8.3}s  wall {:>6.2}s",
        cfg.name,
        r.final_accuracy(),
        r.final_bytes_per_node(),
        r.final_emu_time(),
        r.wall_s
    );
    r
}
