//! Hot-path perf-regression harness: the numbers that gate the round
//! loop, written to `BENCH_hotpath.json` so the trajectory accumulates
//! per PR (the CI `bench-smoke` job uploads it as an artifact).
//!
//! Three sections, all artifact-free:
//!
//! 1. **Aggregate throughput** at `dim` params × 6 neighbors for every
//!    sharing strategy, plus the retained scalar reference for full
//!    sharing *measured in the same run* — the `speedup_vs_scalar` row
//!    is the regression gate for the fused kernels.
//! 2. **Codec throughput**: encode + reusable-buffer decode for every
//!    float codec.
//! 3. **Scheduler round rate**: a 1024-node regular:6 gossip fleet of
//!    pure message-driven state machines (no engine), measuring
//!    node-rounds/s through the virtual-time scheduler — once
//!    untraced and once with span tracing at `sample:0.01`, so the
//!    tracing overhead is a ratcheted number of its own.
//!
//! Quick mode (CI): `cargo bench --bench hotpath -- --quick` or
//! `HOTPATH_QUICK=1` — smaller dim, fewer nodes, shorter budgets; the
//! JSON is written either way.
//!
//! **Perf ratchet** (`--ratchet` or `HOTPATH_RATCHET=1`): the committed
//! `BENCH_hotpath.json` is read as history, new rows are appended with
//! the next `run` id, and each throughput row is compared against the
//! **median** of its prior `(bench, mode, quick)` history — median, so
//! one noisy historical run can't move the bar. A sustained >20% drop
//! exits 2 (after writing the artifact, so the trajectory still
//! records the regression). Empty history is a no-op: the ratchet only
//! tightens once a baseline has accumulated.

use std::collections::HashMap;

use anyhow::Result;

use decentralize_rs::bench::{run, BenchResult};
use decentralize_rs::communication::{Envelope, MsgKind, Payload};
use decentralize_rs::compression::{FloatCodec, Fp16, Qsgd, RawF32};
use decentralize_rs::graph;
use decentralize_rs::kernels::fold::FoldCtx;
use decentralize_rs::kernels::{reference, simd_active, Scratch};
use decentralize_rs::model::ParamVec;
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::scheduler::{EventNode, NodeCtx, Scheduler, Wake};
use decentralize_rs::sharing::{self, Received, Sharing};
use decentralize_rs::trace::{TraceMode, TraceRecorder};
use decentralize_rs::util::json::{parse, Json};

const NEIGHBORS: usize = 6;

/// Ratchet key for the dispatched-kernel rows: the `simd` feature swaps
/// the lane backend, so simd-on and simd-off runs accumulate separate
/// histories and each ratchets against its own baseline.
fn lane_mode() -> &'static str {
    if simd_active() {
        "kernel+simd"
    } else {
        "kernel"
    }
}

fn rand_model(dim: usize, seed: u64) -> ParamVec {
    let mut rng = Xoshiro256pp::new(seed);
    ParamVec::random(dim, 1.0, &mut rng)
}

/// One JSON trajectory row for a timed section.
#[allow(clippy::too_many_arguments)]
fn row(
    bench: &str,
    mode: &str,
    dim: usize,
    res: &BenchResult,
    items_per_iter: f64,
    unit: &str,
    quick: bool,
) -> Json {
    Json::obj(vec![
        ("figure", Json::str("hotpath")),
        ("bench", Json::str(bench)),
        ("mode", Json::str(mode)),
        ("dim", Json::num(dim as f64)),
        ("neighbors", Json::num(NEIGHBORS as f64)),
        ("mean_s", Json::num(res.mean_s)),
        ("median_s", Json::num(res.median_s)),
        ("min_s", Json::num(res.min_s)),
        ("iters", Json::num(res.iters as f64)),
        ("throughput", Json::num(items_per_iter / res.mean_s)),
        ("throughput_unit", Json::str(unit)),
        ("quick", Json::Bool(quick)),
    ])
}

/// Per-sender payloads for one strategy (each sender is its own
/// instance, as in a real fleet; stateful strategies see the common
/// init first).
fn strategy_payloads(spec: &str, dim: usize, init: &ParamVec) -> Vec<Vec<u8>> {
    (0..NEIGHBORS)
        .map(|s| {
            let mut sh = sharing::from_spec(spec, dim, 1000 + s as u64).unwrap();
            sh.set_init(init);
            sh.outgoing(&rand_model(dim, 2000 + s as u64), 0).unwrap()
        })
        .collect()
}

/// Pure message-driven gossip state machine: train-free D-PSGD round
/// loop (broadcast → await all → aggregate → next), exercising the
/// scheduler queue, zero-copy broadcast, and the kernel aggregation.
struct GossipSm {
    id: usize,
    rounds: u64,
    round: u64,
    self_weight: f64,
    neighbors: Vec<(usize, f64)>,
    sharing: Box<dyn Sharing>,
    model: ParamVec,
    pending: HashMap<(u64, usize), Payload>,
    scratch: Scratch,
}

impl GossipSm {
    fn broadcast(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        // Pooled serialization: warm rounds reuse the retained payload
        // buffer, so the broadcast allocates nothing.
        let payload: Payload = self
            .sharing
            .outgoing_pooled(&self.model, self.round, &mut self.scratch)?;
        ctx.note_serialized(payload.len());
        for &(nbr, _) in &self.neighbors {
            ctx.send(Envelope {
                src: self.id,
                dst: nbr,
                round: self.round,
                kind: MsgKind::Model,
                sent_at_s: 0.0,
                trace: 0,
                payload: payload.clone(),
            });
        }
        Ok(())
    }

    fn try_aggregate(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        loop {
            if self.round >= self.rounds {
                return Ok(());
            }
            if !self
                .neighbors
                .iter()
                .all(|&(n, _)| self.pending.contains_key(&(self.round, n)))
            {
                return Ok(());
            }
            let msgs: Vec<(usize, f64, Payload)> = self
                .neighbors
                .iter()
                .map(|&(n, w)| (n, w, self.pending.remove(&(self.round, n)).unwrap()))
                .collect();
            let received: Vec<Received> = msgs
                .iter()
                .map(|(src, weight, payload)| Received {
                    src: *src,
                    weight: *weight,
                    payload: payload.as_slice(),
                })
                .collect();
            self.sharing
                .aggregate_with(&mut self.model, self.self_weight, &received, &mut self.scratch)?;
            self.round += 1;
            if self.round < self.rounds {
                self.broadcast(ctx)?;
            }
        }
    }
}

impl EventNode for GossipSm {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        match wake {
            Wake::Start => self.broadcast(ctx),
            Wake::Message(env) => {
                if env.kind == MsgKind::Model && env.round >= self.round {
                    self.pending.insert((env.round, env.src), env.payload);
                }
                self.try_aggregate(ctx)
            }
            _ => Ok(()),
        }
    }

    fn done(&self) -> bool {
        self.round >= self.rounds
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("HOTPATH_QUICK").is_ok_and(|v| v != "0");
    let ratchet = std::env::args().any(|a| a == "--ratchet")
        || std::env::var("HOTPATH_RATCHET").is_ok_and(|v| v != "0");
    // Committed trajectory = ratchet history. Unreadable/absent files
    // degrade to an empty history (first run seeds the baseline).
    let history: Vec<Json> = std::fs::read_to_string("BENCH_hotpath.json")
        .ok()
        .and_then(|s| parse(&s).ok())
        .and_then(|j| match j {
            Json::Arr(rows) => Some(rows),
            _ => None,
        })
        .unwrap_or_default();
    let run_id = history
        .iter()
        .filter_map(|r| r.get("run").as_f64())
        .fold(0.0, f64::max) as u64
        + 1;
    let dim: usize = if quick { 262_144 } else { 1_048_576 };
    let budget_ms: u64 = if quick { 250 } else { 800 };
    let sched_nodes: usize = if quick { 256 } else { 1024 };
    let sched_rounds: u64 = if quick { 3 } else { 5 };
    println!(
        "== hotpath: round hot-path regression harness (dim = {dim}, {NEIGHBORS} neighbors{}) ==",
        if quick { ", quick" } else { "" }
    );
    let mut rows: Vec<Json> = Vec::new();
    let elems = (dim * NEIGHBORS) as f64;
    let self_w = 1.0 - NEIGHBORS as f64 / (NEIGHBORS + 1) as f64;
    let w = 1.0 / (NEIGHBORS + 1) as f64;
    let init = ParamVec::zeros(dim);

    // --- 1. full-sharing aggregate: fused kernels vs the retained
    //        scalar reference (fresh-vector decode + scalar fold), in
    //        the same run. This ratio is the acceptance gate.
    let full_payloads = strategy_payloads("full", dim, &init);
    let speedup = {
        let received: Vec<Received> = full_payloads
            .iter()
            .enumerate()
            .map(|(s, p)| Received { src: s, weight: w, payload: p })
            .collect();
        let mut sh = sharing::from_spec("full", dim, 0).unwrap();
        let mut model = rand_model(dim, 1);
        let mut scratch = Scratch::new();
        let kernel = run("aggregate/full/kernel", budget_ms, || {
            sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();
        });
        kernel.print_throughput(elems, "param_neighbor");
        rows.push(row(
            "aggregate/full",
            lane_mode(),
            dim,
            &kernel,
            elems,
            "param_neighbors_per_s",
            quick,
        ));

        let mut model_ref = rand_model(dim, 1);
        let scalar = run("aggregate/full/scalar_ref", budget_ms, || {
            reference::scale(model_ref.as_mut_slice(), self_w as f32);
            for r in &received {
                reference::decode_le_axpy(model_ref.as_mut_slice(), r.weight as f32, r.payload);
            }
        });
        scalar.print_throughput(elems, "param_neighbor");
        rows.push(row(
            "aggregate/full",
            "scalar_ref",
            dim,
            &scalar,
            elems,
            "param_neighbors_per_s",
            quick,
        ));
        let speedup = scalar.mean_s / kernel.mean_s;
        println!("aggregate/full: {} is {speedup:.2}x the scalar reference", lane_mode());
        speedup
    };
    rows.push(Json::obj(vec![
        ("figure", Json::str("hotpath")),
        ("bench", Json::str("aggregate/full/speedup")),
        ("mode", Json::str(lane_mode())),
        ("dim", Json::num(dim as f64)),
        ("neighbors", Json::num(NEIGHBORS as f64)),
        ("speedup_vs_scalar", Json::num(speedup)),
        ("meets_2x", Json::Bool(speedup >= 2.0)),
        ("quick", Json::Bool(quick)),
    ]));

    // --- per-strategy aggregate throughput (kernel path) ---
    for spec in ["full:fp16", "quant:64", "subsample:0.1", "topk:0.1", "choco:0.1:0.5"] {
        let payloads = strategy_payloads(spec, dim, &init);
        let received: Vec<Received> = payloads
            .iter()
            .enumerate()
            .map(|(s, p)| Received { src: s, weight: w, payload: p })
            .collect();
        let mut sh = sharing::from_spec(spec, dim, 0).unwrap();
        sh.set_init(&init);
        let mut model = rand_model(dim, 1);
        let mut scratch = Scratch::new();
        // Keyed by the full spec: "full:fp16" must not share a ratchet
        // history with the dense section-1 "aggregate/full" row.
        let name = format!("aggregate/{spec}");
        let res = run(&name, budget_ms, || {
            sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();
        });
        res.print_throughput(elems, "param_neighbor");
        rows.push(row(&name, lane_mode(), dim, &res, elems, "param_neighbors_per_s", quick));
    }

    // --- fold plans at high degree: the per-neighbor fold is the
    //     round-rate bottleneck at degree ≫ 8; compare the serial chain
    //     against a tree:8 plan (same kernels, grouped reduction).
    {
        let fold_dim = dim / 4;
        let fold_degree = 64usize;
        let fold_init = ParamVec::zeros(fold_dim);
        let payloads: Vec<Vec<u8>> = (0..fold_degree)
            .map(|s| {
                let mut sh = sharing::from_spec("full", fold_dim, 5000 + s as u64).unwrap();
                sh.set_init(&fold_init);
                sh.outgoing(&rand_model(fold_dim, 6000 + s as u64), 0).unwrap()
            })
            .collect();
        let wf = 1.0 / (fold_degree + 1) as f64;
        let self_wf = 1.0 - fold_degree as f64 * wf;
        let received: Vec<Received> = payloads
            .iter()
            .enumerate()
            .map(|(s, p)| Received { src: s, weight: wf, payload: p })
            .collect();
        let fold_elems = (fold_dim * fold_degree) as f64;
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut serial_s = f64::NAN;
        for (mode, fold) in
            [("fold:serial", FoldCtx::serial()), ("fold:tree:8", FoldCtx::tree(8, workers))]
        {
            let mut sh = sharing::from_spec("full", fold_dim, 0).unwrap();
            sh.set_fold(fold);
            let mut model = rand_model(fold_dim, 1);
            let mut scratch = Scratch::new();
            let res = run(&format!("aggregate/full_deg{fold_degree}/{mode}"), budget_ms, || {
                sh.aggregate_with(&mut model, self_wf, &received, &mut scratch).unwrap();
            });
            res.print_throughput(fold_elems, "param_neighbor");
            rows.push(Json::obj(vec![
                ("figure", Json::str("hotpath")),
                ("bench", Json::str(format!("aggregate/full_deg{fold_degree}"))),
                ("mode", Json::str(mode)),
                ("dim", Json::num(fold_dim as f64)),
                ("neighbors", Json::num(fold_degree as f64)),
                ("workers", Json::num(workers as f64)),
                ("simd", Json::Bool(simd_active())),
                ("mean_s", Json::num(res.mean_s)),
                ("median_s", Json::num(res.median_s)),
                ("min_s", Json::num(res.min_s)),
                ("iters", Json::num(res.iters as f64)),
                ("throughput", Json::num(fold_elems / res.mean_s)),
                ("throughput_unit", Json::str("param_neighbors_per_s")),
                ("quick", Json::Bool(quick)),
            ]));
            if mode == "fold:serial" {
                serial_s = res.mean_s;
            } else {
                println!(
                    "aggregate/full_deg{fold_degree}: tree:8 on {workers} workers is \
                     {:.2}x the serial fold",
                    serial_s / res.mean_s
                );
            }
        }
    }

    // --- 2. codec encode / decode throughput (reusable decode buffer,
    //        as the aggregation hot path uses it) ---
    {
        let vals = rand_model(dim, 3).into_vec();
        let codecs: [(&str, Box<dyn FloatCodec>); 3] = [
            ("raw_f32", Box::new(RawF32)),
            ("fp16", Box::new(Fp16)),
            ("qsgd128", Box::new(Qsgd::new(128, 1))),
        ];
        for (name, codec) in &codecs {
            let enc_name = format!("codec/{name}/encode");
            let res = run(&enc_name, budget_ms / 2, || {
                std::hint::black_box(codec.encode(&vals));
            });
            res.print_throughput(dim as f64, "elem");
            rows.push(row(&enc_name, "kernel", dim, &res, dim as f64, "elems_per_s", quick));

            let enc = codec.encode(&vals);
            let mut buf: Vec<f32> = Vec::new();
            let dec_name = format!("codec/{name}/decode_into");
            let res = run(&dec_name, budget_ms / 2, || {
                codec.decode_into(&enc, dim, &mut buf).unwrap();
                std::hint::black_box(buf.len());
            });
            res.print_throughput(dim as f64, "elem");
            rows.push(row(&dec_name, "kernel", dim, &res, dim as f64, "elems_per_s", quick));
        }
    }

    // --- 3. scheduler round rate: pure-gossip fleet, no engine. Run
    //        untraced, then with span tracing at sample:0.01 — the
    //        overhead of the tracing hooks is itself a ratcheted number.
    {
        let sched_dim = 1024usize;
        let run_fleet = |tracer: Option<TraceRecorder>| -> f64 {
            let mut rng = Xoshiro256pp::new(42);
            let g = graph::random_regular(sched_nodes, NEIGHBORS, &mut rng).unwrap();
            let mw = graph::metropolis_hastings(&g);
            let mut sched = Scheduler::new(None, 1);
            for id in 0..sched_nodes {
                let neighbors: Vec<(usize, f64)> = mw.neighbor_weights(id).collect();
                sched.add_node(Box::new(GossipSm {
                    id,
                    rounds: sched_rounds,
                    round: 0,
                    self_weight: mw.self_weight(id),
                    neighbors,
                    sharing: sharing::from_spec("full", sched_dim, id as u64).unwrap(),
                    model: rand_model(sched_dim, 77 + id as u64),
                    pending: HashMap::new(),
                    scratch: Scratch::new(),
                }));
            }
            if let Some(rec) = tracer {
                sched.set_tracer(rec);
            }
            let t = std::time::Instant::now();
            sched.run().unwrap();
            t.elapsed().as_secs_f64()
        };
        let node_rounds = (sched_nodes as u64 * sched_rounds) as f64;
        let mut untraced_s = f64::NAN;
        let sampled = TraceRecorder::new(TraceMode::Sample(0.01));
        for (mode, tracer) in [("kernel", None), ("trace:sample:0.01", Some(sampled))] {
            let elapsed = run_fleet(tracer);
            println!(
                "scheduler/round_rate [{mode}]: {sched_nodes} nodes x {sched_rounds} rounds \
                 in {elapsed:.3}s = {:.0} node-rounds/s",
                node_rounds / elapsed
            );
            rows.push(Json::obj(vec![
                ("figure", Json::str("hotpath")),
                ("bench", Json::str("scheduler/round_rate")),
                ("mode", Json::str(mode)),
                ("dim", Json::num(sched_dim as f64)),
                ("nodes", Json::num(sched_nodes as f64)),
                ("rounds", Json::num(sched_rounds as f64)),
                ("wall_s", Json::num(elapsed)),
                ("throughput", Json::num(node_rounds / elapsed)),
                ("throughput_unit", Json::str("node_rounds_per_s")),
                ("quick", Json::Bool(quick)),
            ]));
            if mode == "kernel" {
                untraced_s = elapsed;
            } else {
                println!(
                    "scheduler/trace_overhead: sample:0.01 runs at {:.3}x untraced wall time",
                    elapsed / untraced_s
                );
            }
        }
    }

    // Tag this run's rows and append them to the committed history so
    // the trajectory accumulates per PR.
    for r in rows.iter_mut() {
        if let Json::Obj(m) = r {
            m.insert("run".into(), Json::num(run_id as f64));
        }
    }
    // Ratchet check happens before the write so failures still land in
    // the artifact; the exit happens after.
    let mut regressions: Vec<String> = Vec::new();
    if ratchet {
        for r in &rows {
            let (Some(bench), Some(cur)) =
                (r.get("bench").as_str(), r.get("throughput").as_f64())
            else {
                continue;
            };
            let mode = r.get("mode").as_str().unwrap_or("");
            let mut prior: Vec<f64> = history
                .iter()
                .filter(|h| {
                    h.get("bench").as_str() == Some(bench)
                        && h.get("mode").as_str().unwrap_or("") == mode
                        && h.get("quick").as_bool() == Some(quick)
                })
                .filter_map(|h| h.get("throughput").as_f64())
                .collect();
            if prior.is_empty() {
                continue;
            }
            prior.sort_by(f64::total_cmp);
            let baseline = prior[prior.len() / 2];
            if cur < 0.8 * baseline {
                regressions.push(format!(
                    "{bench} [{mode}]: {cur:.3e} < 80% of median baseline {baseline:.3e} \
                     ({} prior runs)",
                    prior.len()
                ));
            }
        }
    }

    let mut all = history;
    all.extend(rows);
    let artifact = Json::Arr(all).pretty();
    match std::fs::write("BENCH_hotpath.json", &artifact) {
        Ok(()) => println!("trajectory written to BENCH_hotpath.json (run {run_id})"),
        Err(e) => {
            // The artifact IS the point of this harness (the CI job
            // uploads it as the perf trajectory); failing to write it
            // must fail the run, not warn-and-green.
            eprintln!("could not write BENCH_hotpath.json: {e}");
            std::process::exit(1);
        }
    }
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("perf ratchet: {r}");
        }
        eprintln!("perf ratchet: sustained >20% regression vs committed history");
        std::process::exit(2);
    }
    println!("== hotpath done ==");
}
