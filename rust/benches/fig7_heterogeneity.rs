//! Fig 7 (beyond-the-paper) bench: heterogeneity & WAN scenarios on the
//! virtual-time scheduler — a straggler-severity sweep (emulated-clock
//! slowdown at identical byte cost), a geo-clustered WAN matrix vs
//! uniform LAN, and session churn. Skips cleanly without artifacts.

mod fig_common;

use fig_common::{bench_config, engine_or_skip, run_variant};

fn main() {
    println!("== fig7: heterogeneity & WAN scenarios ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    // Straggler severity sweep: 1/8 of the fleet is k× slower; the
    // synchronous rounds pace at the stragglers' speed.
    println!("-- straggler severity sweep (12 nodes, regular:5, 6 rounds) --");
    let mut base_emu = f64::NAN;
    for k in [1u32, 2, 4, 8] {
        let mut cfg = bench_config(&format!("fig7/stragglers_x{k}"));
        cfg.rounds = 6;
        cfg.eval_every = 6;
        cfg.step_time = format!("stragglers:0.125:{k}");
        let r = run_variant(&cfg, &engine);
        if k == 1 {
            base_emu = r.final_emu_time();
        }
        println!(
            "straggler x{k:>2}: emu {:>8.3}s  slowdown {:.2}x",
            r.final_emu_time(),
            r.final_emu_time() / base_emu
        );
    }

    // Per-link WAN: 4 geo clusters (LAN inside, 30-120 ms across) vs the
    // uniform LAN baseline — same bytes, WAN-paced clock.
    println!("-- geo-clustered WAN links vs uniform LAN --");
    let mut lan = bench_config("fig7/links_lan");
    lan.rounds = 6;
    lan.eval_every = 6;
    let mut geo = lan.clone();
    geo.name = "fig7/links_geo4".into();
    geo.link_model = "geo:4".into();
    let r_lan = run_variant(&lan, &engine);
    let r_geo = run_variant(&geo, &engine);
    println!(
        "geo:4 emu {:>8.3}s vs lan {:>8.3}s ({:.2}x)",
        r_geo.final_emu_time(),
        r_lan.final_emu_time(),
        r_geo.final_emu_time() / r_lan.final_emu_time()
    );

    // Replayable churn: dynamic topology drawn over session traces.
    println!("-- session churn (dynamic topology) --");
    let mut churn = bench_config("fig7/churn_sessions");
    churn.dynamic = true;
    churn.churn_trace = "sessions:8:2".into();
    let r_churn = run_variant(&churn, &engine);
    println!(
        "sessions 8on/2off: acc {:.4} (uniform-availability baseline above)",
        r_churn.final_accuracy()
    );
    println!("== fig7 done ==");
}
