//! Figure 3 bench: topology comparison (ring / 5-regular / full /
//! dynamic 5-regular) at reduced scale. Full-resolution harness:
//! `cargo run --release --example topologies`.

mod fig_common;

use fig_common::{bench_config, engine_or_skip, run_variant};

fn main() {
    println!("== fig3: topologies & dynamicity ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    let mut ring = bench_config("fig3/ring");
    ring.topology = "ring".into();
    let mut reg = bench_config("fig3/regular5");
    reg.topology = "regular:5".into();
    let mut full = bench_config("fig3/full");
    full.topology = "full".into();
    let mut dynamic = bench_config("fig3/dynamic5");
    dynamic.topology = "regular:5".into();
    dynamic.dynamic = true;

    let r_ring = run_variant(&ring, &engine);
    let r_reg = run_variant(&reg, &engine);
    let r_full = run_variant(&full, &engine);
    let r_dyn = run_variant(&dynamic, &engine);

    // Paper-shape assertions (soft: printed, not panicking, but flagged).
    let ok_order = r_full.final_accuracy() >= r_reg.final_accuracy()
        && r_reg.final_accuracy() >= r_ring.final_accuracy() - 0.02;
    let t_ratio = r_full.final_emu_time() / r_reg.final_emu_time();
    let b_ratio = r_full.final_bytes_per_node() / r_dyn.final_bytes_per_node();
    println!("shape: per-round accuracy full>=reg5>=ring : {ok_order}");
    println!("shape: full/reg5 emulated round-time ratio : {t_ratio:.2}x (paper ~3x)");
    println!("shape: full/dynamic5 bytes ratio           : {b_ratio:.2}x (paper 51x @256n)");
    println!("== fig3 done ==");
}
