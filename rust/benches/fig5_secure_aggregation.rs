//! Figure 5 bench: secure aggregation vs plain D-PSGD on both synthetic
//! datasets, reduced scale. Full-resolution harness:
//! `cargo run --release --example secure_agg`.

mod fig_common;

use fig_common::{bench_config, engine_or_skip, run_variant};

fn main() {
    println!("== fig5: secure aggregation ==");
    let Some(engine) = engine_or_skip(&["mlp", "celeba"]) else { return };

    let mut plain = bench_config("fig5/cifar_dpsgd");
    plain.topology = "regular:5".into();
    let mut secure = plain.clone();
    secure.name = "fig5/cifar_secure".into();
    secure.secure = true;

    let mut aplain = bench_config("fig5/celeba_dpsgd");
    aplain.topology = "regular:5".into();
    aplain.model = "celeba".into();
    aplain.dataset = "celebas".into();
    let mut asecure = aplain.clone();
    asecure.name = "fig5/celeba_secure".into();
    asecure.secure = true;

    let r_p = run_variant(&plain, &engine);
    let r_s = run_variant(&secure, &engine);
    let r_ap = run_variant(&aplain, &engine);
    let r_as = run_variant(&asecure, &engine);

    let over_c = (r_s.final_bytes_per_node() / r_p.final_bytes_per_node() - 1.0) * 100.0;
    let over_a = (r_as.final_bytes_per_node() / r_ap.final_bytes_per_node() - 1.0) * 100.0;
    println!(
        "shape: CIFAR10-S acc delta {:+.4} | byte overhead {over_c:+.1}% (paper ~ -3% / +3%)",
        r_s.final_accuracy() - r_p.final_accuracy()
    );
    println!(
        "shape: CelebA-S  acc delta {:+.4} | byte overhead {over_a:+.1}% (paper ~  0% / +3%)",
        r_as.final_accuracy() - r_ap.final_accuracy()
    );
    println!("== fig5 done ==");
}
