//! API load harness for the `decentra serve` daemon: drive a live
//! daemon with concurrent status pollers and an SSE consumer while a
//! 1024-node artifact-free sim run executes on the scheduler, and
//! record request throughput + tail latency into the committed
//! `BENCH_hotpath.json` trajectory (same ratchet flow as the `hotpath`
//! harness: rows append with the next `run` id, and `--ratchet` /
//! `HOTPATH_RATCHET=1` compares each throughput row against the median
//! of its prior `(bench, mode, quick)` history, exiting 2 on a
//! sustained >20% drop).
//!
//! Quick mode (CI): `cargo bench --bench api_load -- --quick` or
//! `HOTPATH_QUICK=1` — 256 nodes and a 2s measurement window instead
//! of 1024 nodes and 5s.
//!
//! Everything here goes over real TCP against the daemon's hand-rolled
//! HTTP/1.1 server, so the numbers include parsing, routing, the run
//! table mutex, and telemetry-ring reads — the full observability path
//! a monitoring stack would exercise.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use decentralize_rs::serve::{Daemon, ServeOptions};
use decentralize_rs::util::json::{parse, Json};

/// Concurrent `GET /runs/:id` pollers during the measurement window.
const STATUS_CLIENTS: usize = 4;

/// Read one HTTP/1.1 response (status + headers + `Content-Length`
/// body) off the stream.
fn read_response(stream: &mut TcpStream) -> Result<(u16, String)> {
    let mut head = Vec::new();
    let mut buf = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = head.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut buf)?;
        if n == 0 {
            bail!("connection closed mid-response");
        }
        head.extend_from_slice(&buf[..n]);
    };
    let (header_bytes, rest) = head.split_at(header_end);
    let rest = &rest[4..];
    let text = std::str::from_utf8(header_bytes)?;
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()?;
    let mut content_length = 0usize;
    for line in text.lines().skip(1) {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse()?;
            }
        }
    }
    let mut body = rest.to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&buf[..n]);
    }
    body.truncate(content_length);
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// Issue one request on an open keep-alive connection.
fn request(stream: &mut TcpStream, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    read_response(stream)
}

/// Connect, issue one request, drop the connection.
fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    request(&mut stream, method, path, body)
}

/// Poll `GET /runs/:id` until its status is one of `want`.
fn wait_for_status(addr: SocketAddr, id: u64, want: &[&str], timeout: Duration) -> Result<String> {
    let deadline = Instant::now() + timeout;
    loop {
        let (code, body) = one_shot(addr, "GET", &format!("/runs/{id}"), "")?;
        if code != 200 {
            bail!("GET /runs/{id} returned {code}: {body}");
        }
        let status = parse(&body)
            .ok()
            .and_then(|j| j.get("status").as_str().map(str::to_string))
            .unwrap_or_default();
        if want.contains(&status.as_str()) {
            return Ok(status);
        }
        if Instant::now() > deadline {
            bail!("timed out waiting for status {want:?} on run {id} (last {status:?})");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("HOTPATH_QUICK").is_ok_and(|v| v != "0");
    let ratchet = std::env::args().any(|a| a == "--ratchet")
        || std::env::var("HOTPATH_RATCHET").is_ok_and(|v| v != "0");
    let history: Vec<Json> = std::fs::read_to_string("BENCH_hotpath.json")
        .ok()
        .and_then(|s| parse(&s).ok())
        .and_then(|j| match j {
            Json::Arr(rows) => Some(rows),
            _ => None,
        })
        .unwrap_or_default();
    let run_id = history
        .iter()
        .filter_map(|r| r.get("run").as_f64())
        .fold(0.0, f64::max) as u64
        + 1;
    let nodes: usize = if quick { 256 } else { 1024 };
    let window = Duration::from_secs_f64(if quick { 2.0 } else { 5.0 });
    println!(
        "== api_load: serve daemon under load ({nodes} nodes, {:.0}s window{}) ==",
        window.as_secs_f64(),
        if quick { ", quick" } else { "" }
    );

    // Bind on port 0 and run the daemon in the background; everything
    // below is a real HTTP client.
    let opts = ServeOptions { addr: "127.0.0.1:0".into(), ..ServeOptions::default() };
    let daemon = Daemon::bind(&opts).expect("bind daemon");
    let addr = daemon.local_addr();
    let daemon_thread = std::thread::spawn(move || daemon.run());

    // Long-horizon sim run: it cannot finish inside the window, so the
    // pollers always observe a live fleet; DELETE stops it afterwards.
    let results_dir = std::env::temp_dir().join(format!("apibench-{}", std::process::id()));
    let cfg = Json::obj(vec![
        ("name", Json::str("apibench")),
        ("nodes", Json::num(nodes as f64)),
        ("rounds", Json::num(1_000_000.0)),
        ("eval_every", Json::num(5.0)),
        ("topology", Json::str("ring")),
        ("network", Json::str("none")),
        ("train_total", Json::num(nodes.max(2048) as f64)),
        ("results_dir", Json::str(results_dir.display().to_string())),
    ]);
    let envelope = Json::obj(vec![("driver", Json::str("sim")), ("config", cfg)]);
    let (code, body) = one_shot(addr, "POST", "/runs", &envelope.dump()).expect("submit");
    assert_eq!(code, 201, "POST /runs: {body}");
    let id = parse(&body).unwrap().get("id").as_f64().expect("run id") as u64;
    wait_for_status(addr, id, &["running"], Duration::from_secs(30)).expect("run start");

    // Measurement window: STATUS_CLIENTS keep-alive pollers + one SSE
    // consumer, all against the live run.
    let deadline = Instant::now() + window;
    let stop = Arc::new(AtomicBool::new(false));
    let sse = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || -> usize {
            let mut stream = TcpStream::connect(addr).expect("sse connect");
            stream
                .set_read_timeout(Some(Duration::from_millis(200)))
                .expect("sse read timeout");
            let req = format!("GET /runs/{id}/events HTTP/1.1\r\nHost: bench\r\n\r\n");
            stream.write_all(req.as_bytes()).expect("sse request");
            let mut raw = Vec::new();
            let mut buf = [0u8; 16 * 1024];
            while !stop.load(Ordering::SeqCst) {
                match stream.read(&mut buf) {
                    Ok(0) => break,
                    Ok(n) => raw.extend_from_slice(&buf[..n]),
                    Err(_) => continue, // read timeout: poll the stop flag
                }
            }
            String::from_utf8_lossy(&raw).matches("event: round\n").count()
        })
    };
    let pollers: Vec<_> = (0..STATUS_CLIENTS)
        .map(|_| {
            std::thread::spawn(move || -> Vec<f64> {
                let mut stream = TcpStream::connect(addr).expect("poller connect");
                let path = format!("/runs/{id}");
                let mut latencies = Vec::new();
                while Instant::now() < deadline {
                    let t = Instant::now();
                    let (code, _) = request(&mut stream, "GET", &path, "").expect("status poll");
                    assert_eq!(code, 200);
                    latencies.push(t.elapsed().as_secs_f64());
                }
                latencies
            })
        })
        .collect();
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    for p in pollers {
        latencies.extend(p.join().expect("poller thread"));
    }
    let wall_s = t0.elapsed().as_secs_f64().max(window.as_secs_f64());
    stop.store(true, Ordering::SeqCst);
    let round_events = sse.join().expect("sse thread");

    latencies.sort_by(f64::total_cmp);
    let requests = latencies.len();
    let throughput = requests as f64 / wall_s;
    let p50 = percentile(&latencies, 0.50);
    let p99 = percentile(&latencies, 0.99);
    println!(
        "api/status: {requests} requests in {wall_s:.2}s over {STATUS_CLIENTS} clients \
         = {throughput:.0} req/s (p50 {:.1}us, p99 {:.1}us)",
        p50 * 1e6,
        p99 * 1e6
    );
    println!(
        "api/sse_rounds: {round_events} round events streamed \
         = {:.0} events/s alongside the pollers",
        round_events as f64 / wall_s
    );

    // Stop the run at a round boundary, wait for the executor to land
    // it, then take the daemon down cleanly.
    let (code, body) = one_shot(addr, "DELETE", &format!("/runs/{id}"), "").expect("cancel");
    assert_eq!(code, 200, "DELETE /runs/{id}: {body}");
    let status =
        wait_for_status(addr, id, &["cancelled", "done", "failed"], Duration::from_secs(120))
            .expect("run teardown");
    assert_eq!(status, "cancelled", "expected the cancel flag to stop the run");
    let (code, _) = one_shot(addr, "POST", "/shutdown", "").expect("shutdown");
    assert_eq!(code, 200);
    daemon_thread.join().expect("daemon thread").expect("daemon run");
    let _ = std::fs::remove_dir_all(&results_dir);

    let mut rows = vec![
        Json::obj(vec![
            ("figure", Json::str("api")),
            ("bench", Json::str("api/status")),
            ("mode", Json::str("daemon")),
            ("nodes", Json::num(nodes as f64)),
            ("clients", Json::num(STATUS_CLIENTS as f64)),
            ("requests", Json::num(requests as f64)),
            ("wall_s", Json::num(wall_s)),
            ("throughput", Json::num(throughput)),
            ("throughput_unit", Json::str("requests_per_s")),
            ("p50_latency_s", Json::num(p50)),
            ("p99_latency_s", Json::num(p99)),
            ("quick", Json::Bool(quick)),
        ]),
        Json::obj(vec![
            ("figure", Json::str("api")),
            ("bench", Json::str("api/sse_rounds")),
            ("mode", Json::str("daemon")),
            ("nodes", Json::num(nodes as f64)),
            ("events", Json::num(round_events as f64)),
            ("wall_s", Json::num(wall_s)),
            ("throughput", Json::num(round_events as f64 / wall_s)),
            ("throughput_unit", Json::str("round_events_per_s")),
            ("quick", Json::Bool(quick)),
        ]),
    ];
    for r in rows.iter_mut() {
        if let Json::Obj(m) = r {
            m.insert("run".into(), Json::num(run_id as f64));
        }
    }
    // Same ratchet as hotpath: median of the prior (bench, mode, quick)
    // history, checked before the write so regressions still land in
    // the artifact.
    let mut regressions: Vec<String> = Vec::new();
    if ratchet {
        for r in &rows {
            let (Some(bench), Some(cur)) =
                (r.get("bench").as_str(), r.get("throughput").as_f64())
            else {
                continue;
            };
            let mode = r.get("mode").as_str().unwrap_or("");
            let mut prior: Vec<f64> = history
                .iter()
                .filter(|h| {
                    h.get("bench").as_str() == Some(bench)
                        && h.get("mode").as_str().unwrap_or("") == mode
                        && h.get("quick").as_bool() == Some(quick)
                })
                .filter_map(|h| h.get("throughput").as_f64())
                .collect();
            if prior.is_empty() {
                continue;
            }
            prior.sort_by(f64::total_cmp);
            let baseline = prior[prior.len() / 2];
            if cur < 0.8 * baseline {
                regressions.push(format!(
                    "{bench} [{mode}]: {cur:.3e} < 80% of median baseline {baseline:.3e} \
                     ({} prior runs)",
                    prior.len()
                ));
            }
        }
    }

    let mut all = history;
    all.extend(rows);
    let artifact = Json::Arr(all).pretty();
    match std::fs::write("BENCH_hotpath.json", &artifact) {
        Ok(()) => println!("trajectory written to BENCH_hotpath.json (run {run_id})"),
        Err(e) => {
            eprintln!("could not write BENCH_hotpath.json: {e}");
            std::process::exit(1);
        }
    }
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("perf ratchet: {r}");
        }
        eprintln!("perf ratchet: sustained >20% regression vs committed history");
        std::process::exit(2);
    }
    println!("== api_load done ==");
}
