//! Memory smoke for the shared parameter store (CI job `memory-smoke`):
//! a 512-node gossip fleet on the virtual-time scheduler in shared-store
//! mode, where only a small cohort ever writes. Peak resident parameter
//! bytes must stay bounded by the *divergence* (writers × shard), not by
//! the fleet size — the property that breaks the per-node-buffer scale
//! ceiling. In paged mode the budget tightens further to writers ×
//! *page*: only the pages a writer actually dirties are charged.
//! Artifact-free: nodes mutate parameters directly instead of running
//! the PJRT engine.

use anyhow::Result;

use decentralize_rs::communication::{Envelope, MsgKind, Payload};
use decentralize_rs::scheduler::{EventNode, NodeCtx, Scheduler, Wake};
use decentralize_rs::store::{ParamSlot, ParamStore};

const NODES: usize = 512;
/// 4096 f32 = 16 KiB per shard: big enough that a per-node copy would
/// dominate, small enough for a fast CI run.
const DIM: usize = 4096;
const WRITERS: usize = 32;
const ROUNDS: u64 = 3;

/// Ring-gossip node: every round it (optionally) writes its parameters,
/// broadcasts one shared payload to both ring neighbors, and advances
/// once both neighbor messages for the round arrived.
struct GossipNode {
    id: usize,
    params: ParamSlot,
    writer: bool,
    round: u64,
    /// Per-round arrival counts (a neighbor may run one round ahead).
    arrived: std::collections::HashMap<u64, usize>,
}

impl GossipNode {
    fn do_round(&mut self, ctx: &mut NodeCtx) {
        if self.writer {
            // The only materialization point: writers take (CoW copy on
            // first round), nudge one coordinate, put back.
            let mut v = self.params.take();
            v[self.id % DIM] += 1.0;
            self.params.put(v);
        }
        // One payload serialization per round, shared by both
        // neighbors' envelopes (readers never touch their slot, so
        // they never materialize a shard).
        let payload: Payload = vec![self.round as u8; 64].into();
        ctx.note_serialized(payload.len());
        for dst in [
            (self.id + 1) % NODES,
            (self.id + NODES - 1) % NODES,
        ] {
            ctx.send(Envelope {
                src: self.id,
                dst,
                round: self.round,
                kind: MsgKind::Model,
                sent_at_s: 0.0,
                trace: 0,
                payload: payload.clone(),
            });
        }
    }

    fn advance_if_ready(&mut self, ctx: &mut NodeCtx) {
        while self.round < ROUNDS && self.arrived.get(&self.round).copied().unwrap_or(0) >= 2 {
            self.arrived.remove(&self.round);
            self.round += 1;
            if self.round < ROUNDS {
                self.do_round(ctx);
            }
        }
    }
}

impl EventNode for GossipNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        match wake {
            Wake::Start => {
                self.do_round(ctx);
                Ok(())
            }
            Wake::Message(env) => {
                if env.round >= self.round {
                    *self.arrived.entry(env.round).or_insert(0) += 1;
                }
                self.advance_if_ready(ctx);
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn done(&self) -> bool {
        self.round >= ROUNDS
    }
}

#[test]
fn peak_param_bytes_stay_under_divergence_budget() {
    let shard_bytes = (DIM * 4) as u64;
    let store = ParamStore::from_vec(vec![0.5; DIM]);
    let mut sched = Scheduler::new(None, 4);
    for id in 0..NODES {
        sched.add_node(Box::new(GossipNode {
            id,
            params: ParamSlot::stored(store.register()),
            writer: id < WRITERS,
            round: 0,
            arrived: std::collections::HashMap::new(),
        }));
    }
    // Registration is free: the whole 512-node fleet shares one base.
    let start = store.stats();
    assert_eq!(start.nodes, NODES as u64);
    assert_eq!(start.resident_bytes, 0);
    assert_eq!(start.peak_resident_bytes, 0);
    assert_eq!(start.shared_bytes, shard_bytes);

    sched.run().unwrap();

    // Fixed budget: divergence only. A per-node-copy regression would
    // blow through this by NODES / WRITERS = 16x.
    let stats = store.stats();
    let budget = (WRITERS as u64 + 1) * shard_bytes;
    assert!(
        stats.peak_resident_bytes <= budget,
        "peak {} exceeds divergence budget {} (per-node copies are back?)",
        stats.peak_resident_bytes,
        budget
    );
    assert_eq!(stats.materialized_total, WRITERS as u64);
    assert_eq!(stats.live_shards, WRITERS as u64);
    assert_eq!(stats.resident_bytes, WRITERS as u64 * shard_bytes);

    // Sanity: writers read their writes, readers still see the base.
    let probe = store.register();
    probe.with(|v| assert_eq!(v[0], 0.5));

    // Zero-copy accounting: each node serialized ROUNDS payloads of 64
    // bytes (not 2x — the fan-out shares the buffer), while wire bytes
    // counted both recipients.
    let c = sched.counters(0);
    assert_eq!(c.bytes_serialized, ROUNDS * 64);
    assert_eq!(c.msgs_sent, ROUNDS * 2);
    assert!(c.bytes_sent >= ROUNDS * 2 * 64);
}

#[test]
fn paged_store_charges_pages_not_shards() {
    // Same fleet in paged mode with 1 KiB pages (256 f32, 16 pages per
    // shard). Every writer dirties exactly one page — coordinates
    // 0..WRITERS all land in page 0 of the writer's own shard, each
    // with a distinct bumped coordinate, so interning cannot collapse
    // them — and the divergence charge must be page-granular: one page
    // per writer plus one transient assembled shard, a 16x tighter
    // budget than the unpaged shared store's whole-shard charge.
    const PAGE: usize = 256;
    let shard_bytes = (DIM * 4) as u64;
    let page_bytes = (PAGE * 4) as u64;
    let store = ParamStore::from_vec_paged(vec![0.5; DIM], PAGE);
    let mut sched = Scheduler::new(None, 4);
    for id in 0..NODES {
        sched.add_node(Box::new(GossipNode {
            id,
            params: ParamSlot::stored(store.register()),
            writer: id < WRITERS,
            round: 0,
            arrived: std::collections::HashMap::new(),
        }));
    }
    sched.run().unwrap();

    let stats = store.stats();
    let budget = WRITERS as u64 * page_bytes + shard_bytes;
    assert!(
        stats.peak_resident_bytes <= budget,
        "paged peak {} exceeds page-granular budget {} (whole-shard charges are back?)",
        stats.peak_resident_bytes,
        budget
    );
    // The paged budget itself is far below the unpaged one.
    assert!(budget < (WRITERS as u64 + 1) * shard_bytes / 4);
    assert_eq!(stats.page_size, PAGE as u64);
    assert_eq!(stats.live_shards, WRITERS as u64);
    assert_eq!(stats.materialized_total, WRITERS as u64);
    assert_eq!(stats.live_pages, WRITERS as u64);
    assert_eq!(stats.page_bytes, WRITERS as u64 * page_bytes);
    assert_eq!(stats.resident_bytes, WRITERS as u64 * page_bytes);

    // Readers still see the base through the paged read path, and
    // writers read their own writes.
    let probe = store.register();
    probe.with(|v| {
        assert_eq!(v[0], 0.5);
        assert_eq!(v[DIM - 1], 0.5);
    });
}

#[test]
fn departed_nodes_return_their_shards() {
    // A writer fleet where every node releases at the end models the
    // churn-departure path: all shards are resident at once (the peak),
    // then live shards drain to zero while the peak keeps its mark.
    let store = ParamStore::from_vec(vec![1.0; 256]);
    let mut slots: Vec<_> = (0..8).map(|_| ParamSlot::stored(store.register())).collect();
    for slot in slots.iter_mut() {
        let mut v = slot.take();
        v[0] += 1.0;
        slot.put(v);
    }
    let mid = store.stats();
    assert_eq!(mid.live_shards, 8);
    assert_eq!(mid.resident_bytes, 8 * 256 * 4);
    for mut slot in slots {
        slot.release();
    }
    let stats = store.stats();
    assert_eq!(stats.materialized_total, 8);
    assert_eq!(stats.live_shards, 0);
    assert_eq!(stats.resident_bytes, 0);
    assert_eq!(stats.peak_resident_bytes, 8 * 256 * 4);
}
