//! Integration: PJRT engine executing the real AOT artifacts.
//!
//! Requires `artifacts/` (run `make artifacts`); tests skip politely when
//! missing so plain `cargo test` still passes in a fresh checkout.

use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::runtime::EngineHandle;

/// Artifact/PJRT gate: skip (with a clear message) when artifacts are
/// not built or the engine cannot start (e.g. built without `xla`).
fn engine_or_skip(models: &[&str]) -> Option<EngineHandle> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    match EngineHandle::start(&dir, models) {
        Ok(engine) => Some(engine),
        Err(e) => {
            eprintln!("skipping: PJRT engine unavailable ({e:#})");
            None
        }
    }
}

fn random_batch(
    meta: &decentralize_rs::runtime::ModelMeta,
    batch: usize,
    seed: u64,
) -> (Vec<f32>, Vec<i32>) {
    let (h, w, c) = meta.input_shape;
    let mut rng = Xoshiro256pp::new(seed);
    let x: Vec<f32> = (0..batch * h * w * c).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.range(0, meta.num_classes) as i32).collect();
    (x, y)
}

fn init_params(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect()
}

#[test]
fn train_step_reduces_loss() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let meta = engine.manifest().model("mlp").unwrap().clone();
    let (x, y) = random_batch(&meta, meta.train_batch, 1);
    let mut params = init_params(meta.param_count, 2);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let (p, loss) = engine
            .train_step("mlp", params, x.clone(), y.clone(), 0.05)
            .unwrap();
        params = p;
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.5,
        "loss did not drop: {first} -> {last}"
    );
    engine.shutdown();
}

#[test]
fn eval_counts_are_sane() {
    let Some(engine) = engine_or_skip(&["cnn"]) else { return };
    let meta = engine.manifest().model("cnn").unwrap().clone();
    let (x, y) = random_batch(&meta, meta.eval_batch, 3);
    let params = init_params(meta.param_count, 4);
    let (sum_loss, correct) = engine.eval_batch("cnn", params, x, y).unwrap();
    assert!(sum_loss.is_finite() && sum_loss > 0.0);
    assert!((0..=meta.eval_batch as i32).contains(&correct));
    engine.shutdown();
}

#[test]
fn aggregate_kernel_matches_cpu_reference() {
    let Some(engine) = engine_or_skip(&["cnn"]) else { return };
    let meta = engine.manifest().model("cnn").unwrap().clone();
    let k = meta.agg_k;
    let p = meta.param_count;
    let mut rng = Xoshiro256pp::new(9);
    let stack: Vec<f32> = (0..k * p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    // Random convex weights over the first 5 rows, zeros elsewhere.
    let mut weights = vec![0.0f32; k];
    let mut total = 0.0f32;
    for w in weights.iter_mut().take(5) {
        *w = rng.next_f32();
        total += *w;
    }
    for w in weights.iter_mut().take(5) {
        *w /= total;
    }
    let got = engine.aggregate("cnn", stack.clone(), weights.clone()).unwrap();
    for i in 0..p {
        let want: f32 = (0..k).map(|r| weights[r] * stack[r * p + i]).sum();
        assert!((got[i] - want).abs() < 1e-4, "coord {i}: {} vs {want}", got[i]);
    }
    engine.shutdown();
}

#[test]
fn sparsify_kernel_error_feedback_invariants() {
    let Some(engine) = engine_or_skip(&["celeba"]) else { return };
    let meta = engine.manifest().model("celeba").unwrap().clone();
    let p = meta.param_count;
    let mut rng = Xoshiro256pp::new(11);
    let values: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let residual: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 0.2)).collect();
    let (sent, new_r) = engine
        .sparsify("celeba", values.clone(), residual.clone(), 0.8)
        .unwrap();
    for i in 0..p {
        let corrected = values[i] + residual[i];
        assert!((sent[i] + new_r[i] - corrected).abs() < 1e-5, "mass at {i}");
        assert!(sent[i] * new_r[i] == 0.0, "disjoint support at {i}");
        if corrected.abs() >= 0.8 {
            assert_eq!(new_r[i], 0.0, "large value kept at {i}");
        } else {
            assert_eq!(sent[i], 0.0, "small value sent at {i}");
        }
    }
    engine.shutdown();
}

#[test]
fn concurrent_callers_share_engine() {
    let Some(engine) = engine_or_skip(&["cnn"]) else { return };
    let meta = engine.manifest().model("cnn").unwrap().clone();
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let engine = engine.clone();
            let meta = meta.clone();
            s.spawn(move || {
                let (x, y) = random_batch(&meta, meta.train_batch, t);
                let mut params = init_params(meta.param_count, t + 10);
                for _ in 0..5 {
                    let (p, loss) = engine
                        .train_step("cnn", params, x.clone(), y.clone(), 0.05)
                        .unwrap();
                    params = p;
                    assert!(loss.is_finite());
                }
            });
        }
    });
    engine.shutdown();
}

#[test]
fn bad_arg_shapes_rejected_before_execution() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let err = engine.train_step("mlp", vec![0.0; 3], vec![0.0; 3], vec![0], 0.1);
    assert!(err.is_err());
    let err2 = engine.eval_batch("nope", vec![], vec![], vec![]);
    assert!(err2.is_err());
    engine.shutdown();
}
