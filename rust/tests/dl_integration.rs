//! End-to-end integration: full decentralized training runs through the
//! coordinator (dataset -> partition -> topology -> nodes -> PJRT train
//! steps -> sharing -> aggregation -> metrics). Requires artifacts.

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::run_experiment;
use decentralize_rs::runtime::EngineHandle;

/// Artifact/PJRT gate: tests need compiled XLA artifacts AND a build
/// with the `xla` feature; skip with a clear message when either is
/// missing so `cargo test` stays green in a fresh checkout.
fn engine_or_skip(models: &[&str]) -> Option<EngineHandle> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    match EngineHandle::start(&dir, models) {
        Ok(engine) => Some(engine),
        Err(e) => {
            eprintln!("skipping: PJRT engine unavailable ({e:#})");
            None
        }
    }
}

fn small_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = name.into();
    cfg.nodes = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.train_total = 480;
    cfg.test_total = 96;
    cfg.topology = "regular:3".into();
    cfg.local_steps = 2;
    cfg
}

#[test]
fn dl_training_learns_and_logs() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_dl_basic");
    cfg.rounds = 16;
    let result = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(result.logs.len(), 6);
    // Every node logged the same rounds.
    for log in &result.logs {
        assert_eq!(log.records.len(), result.logs[0].records.len());
        assert!(!log.records.is_empty());
    }
    // Learning signal: accuracy well above chance (10 classes) by the end.
    let acc = result.final_accuracy();
    assert!(acc > 0.25, "final accuracy {acc}");
    // Train loss decreased.
    let first = result.series.first().unwrap().train_loss.mean;
    let last = result.series.last().unwrap().train_loss.mean;
    assert!(last < first, "train loss {first} -> {last}");
    // Bytes accounted: 3 neighbors * (P*4 + header) per round.
    let bytes = result.final_bytes_per_node();
    assert!(bytes > 0.0);
    engine.shutdown();
}

#[test]
fn all_nodes_converge_to_similar_accuracy() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let cfg = small_cfg("it_dl_consensus");
    let result = run_experiment(&cfg, &engine).unwrap();
    let last = result.series.last().unwrap();
    // 95% CI across nodes should be modest relative to the mean:
    // aggregation keeps models close.
    assert!(last.test_acc.ci95 < 0.2, "acc spread {}", last.test_acc.ci95);
    engine.shutdown();
}

#[test]
fn dynamic_topology_via_peer_sampler() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_dl_dynamic");
    cfg.dynamic = true;
    let result = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(result.logs.len(), 6);
    assert!(result.final_accuracy() > 0.1);
    engine.shutdown();
}

#[test]
fn sparsification_sends_fewer_bytes() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut full = small_cfg("it_full");
    full.rounds = 4;
    full.eval_every = 4;
    let mut sub = full.clone();
    sub.name = "it_subsample".into();
    sub.sharing = "subsample:0.1".into();
    let mut choco = full.clone();
    choco.name = "it_choco".into();
    choco.sharing = "choco:0.1:0.5".into();
    let rf = run_experiment(&full, &engine).unwrap();
    let rs = run_experiment(&sub, &engine).unwrap();
    let rc = run_experiment(&choco, &engine).unwrap();
    let bf = rf.final_bytes_per_node();
    let bs = rs.final_bytes_per_node();
    let bc = rc.final_bytes_per_node();
    // ~10x reduction (plus index overhead).
    assert!(bs < bf * 0.2, "subsample bytes {bs} vs full {bf}");
    assert!(bc < bf * 0.2, "choco bytes {bc} vs full {bf}");
    engine.shutdown();
}

#[test]
fn secure_aggregation_matches_plain_dpsgd_closely() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut plain = small_cfg("it_plain");
    plain.rounds = 10;
    plain.eval_every = 5;
    let mut secure = plain.clone();
    secure.name = "it_secure".into();
    secure.secure = true;
    let rp = run_experiment(&plain, &engine).unwrap();
    let rs = run_experiment(&secure, &engine).unwrap();
    // Accuracy within a few points (float mask residue only).
    let da = (rp.final_accuracy() - rs.final_accuracy()).abs();
    assert!(da < 0.15, "accuracy gap {da}");
    // Secure costs more bytes (seeds + keys), but only slightly.
    let bp = rp.final_bytes_per_node();
    let bs = rs.final_bytes_per_node();
    assert!(bs > bp, "secure {bs} <= plain {bp}");
    assert!(bs < bp * 1.25, "secure overhead too large: {bs} vs {bp}");
    engine.shutdown();
}

#[test]
fn scheduler_matches_threaded_path_exactly() {
    // The virtual-time scheduler must be a pure execution-strategy
    // change: on a static topology, final per-node metrics are
    // bit-identical to the thread-per-node path.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut threaded = small_cfg("it_runner_threads");
    threaded.nodes = 16;
    threaded.rounds = 6;
    threaded.eval_every = 3;
    threaded.train_total = 640;
    threaded.topology = "regular:4".into();
    threaded.runner = "threads".into();
    let mut sched = threaded.clone();
    sched.name = "it_runner_scheduler".into();
    sched.runner = "scheduler".into();
    let rt = run_experiment(&threaded, &engine).unwrap();
    let rs = run_experiment(&sched, &engine).unwrap();
    assert_eq!(rt.logs.len(), rs.logs.len());
    for (lt, ls) in rt.logs.iter().zip(rs.logs.iter()) {
        assert_eq!(lt.node, ls.node);
        assert_eq!(lt.records.len(), ls.records.len(), "node {}", lt.node);
        let (ft, fs) = (lt.records.last().unwrap(), ls.records.last().unwrap());
        assert_eq!(ft.test_acc, fs.test_acc, "node {} accuracy", lt.node);
        assert_eq!(ft.test_loss, fs.test_loss, "node {} loss", lt.node);
        assert_eq!(ft.train_loss, fs.train_loss, "node {} train loss", lt.node);
        assert_eq!(ft.bytes_sent, fs.bytes_sent, "node {} bytes", lt.node);
    }
    assert_eq!(rt.final_accuracy(), rs.final_accuracy());
    engine.shutdown();
}

#[test]
fn scheduler_runs_dynamic_and_secure_configs() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut dynamic = small_cfg("it_sched_dynamic");
    dynamic.dynamic = true;
    dynamic.runner = "scheduler".into();
    let rd = run_experiment(&dynamic, &engine).unwrap();
    assert_eq!(rd.logs.len(), dynamic.nodes);
    assert!(rd.final_accuracy() > 0.1);
    let mut secure = small_cfg("it_sched_secure");
    secure.secure = true;
    secure.runner = "scheduler".into();
    let mut secure_threads = secure.clone();
    secure_threads.name = "it_sched_secure_threads".into();
    secure_threads.runner = "threads".into();
    let rs = run_experiment(&secure, &engine).unwrap();
    let rst = run_experiment(&secure_threads, &engine).unwrap();
    // Secure aggregation is static-topology: the two runners must also
    // agree bit-for-bit here.
    assert_eq!(rs.final_accuracy(), rst.final_accuracy());
    engine.shutdown();
}

#[test]
fn run_result_saves_and_reloads() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_dl_save");
    cfg.rounds = 4;
    cfg.results_dir = std::env::temp_dir().join("decentra_it_results");
    let _ = std::fs::remove_dir_all(cfg.results_dir.join(&cfg.name));
    let result = run_experiment(&cfg, &engine).unwrap();
    let dir = result.save().unwrap();
    let logs = decentralize_rs::metrics::NodeLog::load_dir(&dir).unwrap();
    assert_eq!(logs.len(), cfg.nodes);
    let series = decentralize_rs::metrics::aggregate(&logs);
    assert_eq!(series.len(), result.series.len());
    let cfg2 = ExperimentConfig::from_file(&dir.join("config.json")).unwrap();
    assert_eq!(cfg2.nodes, cfg.nodes);
    engine.shutdown();
}

#[test]
fn degenerate_scenario_is_bit_identical() {
    // The scenario subsystem must be a pure extension: a run whose
    // scenario axes are all degenerate (straggler factor 1, one geo
    // cluster == uniform LAN matrix, no churn trace) is bit-identical
    // to the plain PR-1 scheduler path. (Emulated time is not compared:
    // the per-run step-time calibration measures real wall-clock.)
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut plain = small_cfg("it_scen_plain");
    plain.rounds = 6;
    plain.eval_every = 3;
    let mut degen = plain.clone();
    degen.name = "it_scen_degen".into();
    degen.step_time = "stragglers:0.5:1".into();
    degen.link_model = "geo:1".into();
    let rp = run_experiment(&plain, &engine).unwrap();
    let rd = run_experiment(&degen, &engine).unwrap();
    assert_eq!(rp.logs.len(), rd.logs.len());
    for (lp, ld) in rp.logs.iter().zip(rd.logs.iter()) {
        assert_eq!(lp.node, ld.node);
        assert_eq!(lp.records.len(), ld.records.len(), "node {}", lp.node);
        for (a, b) in lp.records.iter().zip(ld.records.iter()) {
            assert_eq!(a.test_acc, b.test_acc, "node {} acc", lp.node);
            assert_eq!(a.test_loss, b.test_loss, "node {} loss", lp.node);
            assert_eq!(a.train_loss, b.train_loss, "node {} train loss", lp.node);
            assert_eq!(a.bytes_sent, b.bytes_sent, "node {} bytes", lp.node);
        }
    }
    engine.shutdown();
}

#[test]
fn straggler_scenario_stretches_virtual_time() {
    // 8x stragglers delay their neighbors' AwaitModels states, so the
    // same experiment takes strictly longer on the emulated clock while
    // exchanging exactly the same bytes.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut uniform = small_cfg("it_scen_uniform");
    uniform.rounds = 4;
    uniform.eval_every = 4;
    let mut slow = uniform.clone();
    slow.name = "it_scen_stragglers".into();
    slow.step_time = "stragglers:0.3:8".into();
    let ru = run_experiment(&uniform, &engine).unwrap();
    let rs = run_experiment(&slow, &engine).unwrap();
    assert!(
        rs.final_emu_time() > ru.final_emu_time() * 1.5,
        "straggled {} vs uniform {}",
        rs.final_emu_time(),
        ru.final_emu_time()
    );
    assert_eq!(ru.final_bytes_per_node(), rs.final_bytes_per_node());
    engine.shutdown();
}

#[test]
fn churn_trace_static_run_with_departures_completes() {
    // Static topology + departures trace: departing nodes push their
    // final update and leave; everyone else keeps training on the
    // filtered neighbor sets and the run terminates cleanly.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_scen_departures");
    cfg.rounds = 12;
    cfg.eval_every = 3;
    cfg.churn_trace = "departures:0.3".into();
    let r = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(r.logs.len(), cfg.nodes);
    // Survivors logged the full experiment.
    let max_records = r.logs.iter().map(|l| l.records.len()).max().unwrap();
    assert_eq!(max_records, 4);
    engine.shutdown();
}

#[test]
fn churn_trace_dynamic_sessions_converge() {
    // Dynamic topology + session churn: the sampler draws each round's
    // graph over the trace's active set; training still converges.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_scen_sessions");
    cfg.dynamic = true;
    cfg.churn_trace = "sessions:8:2".into();
    cfg.rounds = 12;
    let r = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(r.logs.len(), cfg.nodes);
    assert!(r.final_accuracy() > 0.15, "acc {}", r.final_accuracy());
    engine.shutdown();
}

#[test]
fn wan_scenario_run_completes() {
    // The headline scenario: stragglers + geo-clustered WAN links +
    // churn sessions in one run (a small-scale version of
    // examples/configs/wan_scenario.json).
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_scen_wan");
    cfg.rounds = 6;
    cfg.eval_every = 3;
    cfg.step_time = "stragglers:0.25:4".into();
    cfg.link_model = "geo:3".into();
    cfg.churn_trace = "sessions:10:2".into();
    let r = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(r.logs.len(), cfg.nodes);
    // Inter-cluster latency is >= 30 ms per hop and every node has at
    // most one intra-cluster neighbor (3 clusters of 2, regular:3), so
    // each of the 6 rounds waits on at least one WAN link — the clock
    // must run well past a uniform-LAN baseline even with calibration
    // noise between the two runs.
    let mut lan = cfg.clone();
    lan.name = "it_scen_wan_baseline".into();
    lan.step_time = "uniform".into();
    lan.link_model = "uniform".into();
    lan.churn_trace = String::new();
    let rl = run_experiment(&lan, &engine).unwrap();
    assert!(
        r.final_emu_time() > rl.final_emu_time() + 0.1,
        "wan {} vs lan {}",
        r.final_emu_time(),
        rl.final_emu_time()
    );
    engine.shutdown();
}

#[test]
fn churn_training_still_converges() {
    // FedScale-style availability churn (paper future work): 25% of the
    // nodes sit out each round; topology is drawn over the active set.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_dl_churn");
    cfg.dynamic = true;
    cfg.churn = 0.25;
    cfg.rounds = 12;
    let result = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(result.logs.len(), cfg.nodes);
    assert!(result.final_accuracy() > 0.2, "acc {}", result.final_accuracy());
    engine.shutdown();
}

#[test]
fn quantized_sharing_runs_end_to_end() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_dl_quant");
    cfg.sharing = "quant:128".into();
    let rq = run_experiment(&cfg, &engine).unwrap();
    let full = small_cfg("it_dl_quant_baseline");
    let rf = run_experiment(&full, &engine).unwrap();
    // ~4x byte reduction (1 byte/param vs 4).
    assert!(rq.final_bytes_per_node() < rf.final_bytes_per_node() * 0.3);
    assert!(rq.final_accuracy() > 0.2);
    engine.shutdown();
}

#[test]
fn fp16_full_sharing_halves_bytes() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_dl_fp16");
    cfg.sharing = "full:fp16".into();
    cfg.rounds = 4;
    cfg.eval_every = 4;
    let rh = run_experiment(&cfg, &engine).unwrap();
    let mut raw = cfg.clone();
    raw.name = "it_dl_fp16_base".into();
    raw.sharing = "full".into();
    let rr = run_experiment(&raw, &engine).unwrap();
    let ratio = rh.final_bytes_per_node() / rr.final_bytes_per_node();
    assert!((0.45..0.6).contains(&ratio), "ratio {ratio}");
    engine.shutdown();
}

// ---------------------------------------------------------------------
// Asynchronous gossip (mode = "async_dl").
// ---------------------------------------------------------------------

/// Find a seed whose derived scenario satisfies `want` (e.g. "at least
/// one straggler was actually drawn"), so Bernoulli scenario draws can
/// never make an assertion vacuous. Deterministic.
fn seed_where(
    cfg: &decentralize_rs::config::ExperimentConfig,
    want: impl Fn(&decentralize_rs::scenario::Scenario) -> bool,
) -> u64 {
    for seed in 1..1000u64 {
        let scenario = decentralize_rs::scenario::Scenario::from_specs(
            &cfg.step_time,
            &cfg.link_model,
            &cfg.churn_trace,
            &cfg.byzantine,
            None,
            cfg.nodes,
            cfg.rounds,
            seed,
        )
        .unwrap();
        if want(&scenario) {
            return seed;
        }
    }
    panic!("no seed under 1000 produced the wanted scenario draw");
}

#[test]
fn async_dl_trains_and_logs_staleness_metrics() {
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_async_basic");
    cfg.mode = "async_dl".into();
    cfg.deadline = "factor:2".into();
    cfg.staleness = "linear:5".into();
    cfg.rounds = 12;
    cfg.eval_every = 4;
    let result = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(result.logs.len(), cfg.nodes);
    for log in &result.logs {
        assert_eq!(log.records.len(), 3, "node {}", log.node);
        // Mean staleness is populated (every aggregated model has a
        // positive virtual age: at least its own transfer time).
        assert!(
            log.records.last().unwrap().mean_staleness_s > 0.0,
            "node {} has no staleness signal",
            log.node
        );
    }
    // Async gossip still learns on this task.
    let acc = result.final_accuracy();
    assert!(acc > 0.2, "final accuracy {acc}");
    engine.shutdown();
}

#[test]
fn async_dl_bit_identical_across_worker_counts() {
    // One shared prepare() (so the calibrated step time is identical),
    // then the same experiment on 1 / 4 / 8 pool workers: every metric
    // except real wall-clock must match bit-for-bit.
    use decentralize_rs::coordinator::{prepare, RunHooks, Runner, SchedulerRunner};
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_async_workers");
    cfg.mode = "async_dl".into();
    cfg.deadline = "factor:2".into();
    cfg.staleness = "poly:0.5".into();
    cfg.rounds = 6;
    cfg.eval_every = 3;
    cfg.step_time = "stragglers:0.25:4".into();
    let setup = prepare(&cfg, &engine).unwrap();
    let mut runs = Vec::new();
    for workers in [1usize, 4, 8] {
        let mut logs = SchedulerRunner { workers }.run(&cfg, &engine, &setup, &RunHooks::default()).unwrap().logs;
        logs.sort_by_key(|l| l.node);
        runs.push(logs);
    }
    for other in &runs[1..] {
        assert_eq!(runs[0].len(), other.len());
        for (a, b) in runs[0].iter().zip(other.iter()) {
            assert_eq!(a.node, b.node);
            assert_eq!(a.records.len(), b.records.len(), "node {}", a.node);
            for (ra, rb) in a.records.iter().zip(b.records.iter()) {
                assert_eq!(ra.round, rb.round, "node {}", a.node);
                assert_eq!(ra.emu_time_s, rb.emu_time_s, "node {}", a.node);
                assert_eq!(ra.train_loss, rb.train_loss, "node {}", a.node);
                assert_eq!(ra.test_loss, rb.test_loss, "node {}", a.node);
                assert_eq!(ra.test_acc, rb.test_acc, "node {}", a.node);
                assert_eq!(ra.bytes_sent, rb.bytes_sent, "node {}", a.node);
                assert_eq!(ra.bytes_recv, rb.bytes_recv, "node {}", a.node);
                assert_eq!(ra.msgs_sent, rb.msgs_sent, "node {}", a.node);
                assert_eq!(ra.late_msgs, rb.late_msgs, "node {}", a.node);
                assert_eq!(ra.dropped_msgs, rb.dropped_msgs, "node {}", a.node);
                assert_eq!(ra.mean_staleness_s, rb.mean_staleness_s, "node {}", a.node);
            }
        }
    }
    engine.shutdown();
}

#[test]
fn async_dl_beats_sync_virtual_time_under_stragglers() {
    // The fig8 claim at test scale: with 10x stragglers, synchronous
    // rounds pace at the stragglers' speed while async nodes close
    // their windows on their own deadlines — same experiment, strictly
    // less virtual time, comparable accuracy.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut sync_cfg = small_cfg("it_async_vs_sync_base");
    sync_cfg.nodes = 12;
    sync_cfg.train_total = 960;
    sync_cfg.topology = "regular:4".into();
    sync_cfg.rounds = 8;
    sync_cfg.eval_every = 4;
    sync_cfg.step_time = "stragglers:0.1:10".into();
    sync_cfg.seed = seed_where(&sync_cfg, |s| !s.compute.is_uniform());
    let mut async_cfg = sync_cfg.clone();
    async_cfg.name = "it_async_vs_sync_async".into();
    async_cfg.mode = "async_dl".into();
    async_cfg.deadline = "factor:2".into();
    async_cfg.staleness = "linear:10".into();
    let rs = run_experiment(&sync_cfg, &engine).unwrap();
    let ra = run_experiment(&async_cfg, &engine).unwrap();
    assert!(
        ra.final_emu_time() < rs.final_emu_time() * 0.8,
        "async {} vs sync {}",
        ra.final_emu_time(),
        rs.final_emu_time()
    );
    // Asynchrony must not wreck convergence on this task.
    assert!(
        ra.final_accuracy() > rs.final_accuracy() - 0.15,
        "async acc {} vs sync acc {}",
        ra.final_accuracy(),
        rs.final_accuracy()
    );
    engine.shutdown();
}

#[test]
fn async_dl_crash_mid_round_completes_without_deadlock() {
    // A crashes: trace kills nodes at virtual instants (not round
    // boundaries). Fixed per-round deadlines make the virtual span
    // machine-independent: 8 rounds x 0.3 s = 2.4 s, crashes land in
    // (0, 1.5), so at least one node dies mid-run and its neighbors
    // finish on timeouts instead of deadlocking.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_async_crash");
    cfg.mode = "async_dl".into();
    cfg.deadline = "fixed:0.3".into();
    cfg.staleness = "linear:2".into();
    cfg.rounds = 8;
    cfg.eval_every = 2;
    cfg.churn_trace = "crashes:0.4:1.5".into();
    cfg.seed = seed_where(&cfg, |s| {
        s.churn.as_ref().is_some_and(|t| {
            let crashed = (0..6).filter(|&i| t.crash_time(i).is_some()).count();
            (1..6).contains(&crashed) // some crash, some survive
        })
    });
    let result = run_experiment(&cfg, &engine).unwrap();
    assert_eq!(result.logs.len(), cfg.nodes);
    let max_records = result.logs.iter().map(|l| l.records.len()).max().unwrap();
    let min_records = result.logs.iter().map(|l| l.records.len()).min().unwrap();
    // Survivors logged every eval; at least one casualty logged fewer.
    assert_eq!(max_records, 4, "survivors should reach round 8");
    assert!(min_records < 4, "a crashed node cannot have a full log");
    engine.shutdown();
}

#[test]
fn async_dl_drop_policy_counts_dropped_messages() {
    // With a WAN link model and a tight fixed deadline, some messages
    // are still in flight when windows close; under late = "drop" they
    // are counted instead of buffered.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_async_drop");
    cfg.mode = "async_dl".into();
    cfg.deadline = "fixed:0.05".into();
    cfg.staleness = "none".into();
    cfg.late = "drop".into();
    cfg.link_model = "geo:3".into();
    cfg.rounds = 6;
    cfg.eval_every = 6;
    // Guarantee at least one inter-cluster link slower than the window,
    // so a late message is structurally unavoidable.
    cfg.seed = seed_where(&cfg, |s| match &s.links {
        Some(decentralize_rs::communication::shaper::LinkModel::Matrix(m)) => (0..cfg.nodes)
            .any(|a| (0..cfg.nodes).any(|b| m.link(a, b).0 > 0.06)),
        _ => false,
    });
    let result = run_experiment(&cfg, &engine).unwrap();
    let total_dropped: u64 = result
        .logs
        .iter()
        .map(|l| l.records.last().unwrap().dropped_msgs)
        .sum();
    let total_late: u64 = result
        .logs
        .iter()
        .map(|l| l.records.last().unwrap().late_msgs)
        .sum();
    // 30+ ms inter-cluster latency vs 50 ms windows: some messages must
    // miss the cut, and the drop policy never buffers them.
    assert!(total_dropped > 0, "geo WAN + 50 ms windows produced no late messages");
    assert_eq!(total_late, 0, "drop policy must not buffer late messages");
    engine.shutdown();
}

#[test]
fn shared_param_store_bit_identical_to_owned_across_workers() {
    // The acceptance gate for the shared parameter store: a 128-node
    // scheduler run produces bit-identical per-node metrics in
    // param_store = "shared" vs "owned", each across worker counts 1/4
    // (one shared prepare() so calibration is common), and the store
    // report shows registration cost O(1) in node count.
    use decentralize_rs::coordinator::{prepare, RunHooks, Runner, SchedulerRunner};
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_param_store");
    cfg.nodes = 128;
    cfg.rounds = 3;
    cfg.eval_every = 3;
    cfg.train_total = 1280;
    cfg.test_total = 64;
    cfg.topology = "regular:4".into();
    cfg.local_steps = 1;
    let setup = prepare(&cfg, &engine).unwrap();
    let mut runs = Vec::new();
    for store_mode in ["owned", "shared"] {
        for workers in [1usize, 4] {
            let mut c = cfg.clone();
            c.param_store = store_mode.into();
            let out = SchedulerRunner { workers }.run(&c, &engine, &setup).unwrap();
            if store_mode == "shared" {
                let report = out.store.expect("shared mode must report store stats");
                // Before round 0 the whole fleet shares one base.
                assert_eq!(report.at_start.nodes, 128);
                assert_eq!(report.at_start.resident_bytes, 0);
                // Every node trains, so every node diverged; peak covers
                // exactly the divergence, not per-node init copies.
                assert_eq!(report.at_end.materialized_total, 128);
                assert!(report.at_end.peak_resident_bytes >= report.at_end.resident_bytes);
            } else {
                assert!(out.store.is_none(), "owned mode must not report a store");
            }
            let mut logs = out.logs;
            logs.sort_by_key(|l| l.node);
            runs.push(logs);
        }
    }
    for other in &runs[1..] {
        assert_eq!(runs[0].len(), other.len());
        for (a, b) in runs[0].iter().zip(other.iter()) {
            assert_eq!(a.node, b.node);
            assert_eq!(a.records.len(), b.records.len(), "node {}", a.node);
            for (ra, rb) in a.records.iter().zip(b.records.iter()) {
                assert_eq!(ra.round, rb.round, "node {}", a.node);
                assert_eq!(ra.train_loss, rb.train_loss, "node {}", a.node);
                assert_eq!(ra.test_loss, rb.test_loss, "node {}", a.node);
                assert_eq!(ra.test_acc, rb.test_acc, "node {}", a.node);
                assert_eq!(ra.bytes_sent, rb.bytes_sent, "node {}", a.node);
                assert_eq!(ra.bytes_recv, rb.bytes_recv, "node {}", a.node);
                assert_eq!(ra.msgs_sent, rb.msgs_sent, "node {}", a.node);
                assert_eq!(ra.bytes_serialized, rb.bytes_serialized, "node {}", a.node);
            }
        }
    }
    engine.shutdown();
}

#[test]
fn shared_param_store_threaded_runner_matches_scheduler() {
    // Shared mode is runner-agnostic: the threaded path over the same
    // prepare() agrees with the scheduler bit-for-bit, and its store
    // report carries the same peak shape (all nodes trained).
    use decentralize_rs::coordinator::{prepare, RunHooks, Runner, SchedulerRunner, ThreadedRunner};
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = small_cfg("it_param_store_threads");
    cfg.nodes = 16;
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg.train_total = 640;
    cfg.topology = "regular:4".into();
    cfg.param_store = "shared".into();
    let setup = prepare(&cfg, &engine).unwrap();
    let sched = SchedulerRunner { workers: 4 }.run(&cfg, &engine, &setup, &RunHooks::default()).unwrap();
    let threads = ThreadedRunner.run(&cfg, &engine, &setup, &RunHooks::default()).unwrap();
    let (mut ls, mut lt) = (sched.logs, threads.logs);
    ls.sort_by_key(|l| l.node);
    lt.sort_by_key(|l| l.node);
    for (a, b) in ls.iter().zip(lt.iter()) {
        let (ra, rb) = (a.records.last().unwrap(), b.records.last().unwrap());
        assert_eq!(ra.test_acc, rb.test_acc, "node {}", a.node);
        assert_eq!(ra.train_loss, rb.train_loss, "node {}", a.node);
        assert_eq!(ra.bytes_sent, rb.bytes_sent, "node {}", a.node);
        assert_eq!(ra.bytes_serialized, rb.bytes_serialized, "node {}", a.node);
    }
    let rs = sched.store.unwrap();
    let rt = threads.store.unwrap();
    assert_eq!(rs.at_end.materialized_total, 16);
    assert_eq!(rt.at_end.materialized_total, 16);
    // Threaded nodes release on thread exit; the scheduler keeps shards
    // live until the run is torn down. Peaks agree.
    assert_eq!(rs.at_end.peak_resident_bytes, rt.at_end.peak_resident_bytes);
    engine.shutdown();
}
