//! Byzantine scenario semantics, artifact-free where possible: a
//! Sharing-level fleet simulation (real strategies, real roster, real
//! defense accounting — synthetic "training" that drifts models toward
//! a known target) shows honest nodes surviving poisoning under the
//! robust rules while plain averaging collapses; flood junk is
//! isolated and its admitted mass bounded; a 256-node poisoned fleet
//! (the CI smoke target) reports a nonzero isolation rate in
//! milliseconds; and a scheduler-level skeleton proves attack traffic
//! — payload bits, flood amplification, arrival accounting — is
//! bit-identical across worker counts. Full-fidelity training runs
//! (the ±2% accuracy acceptance criterion) are gated on compiled
//! artifacts exactly like `dl_integration.rs`.

use std::collections::HashSet;

use decentralize_rs::communication::{Envelope, MsgKind};
use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::{prepare, run_experiment, RunHooks, Runner, SchedulerRunner};
use decentralize_rs::model::ParamVec;
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::scenario::ByzantineRoster;
use decentralize_rs::scheduler::{ComputeOutput, EventNode, NodeCtx, Scheduler, Wake};
use decentralize_rs::sharing::{self, DefenseStats, Received, Sharing};

// ---------------------------------------------------------------------
// Sharing-level fleet simulation (no training engine).
// ---------------------------------------------------------------------

/// Smallest seed whose Bernoulli roster draw lands `count()` inside
/// `band` — the same pin-the-draw idiom as fig8's straggler seed, so
/// the assertions below never depend on a lucky tail of the binomial.
fn seed_with_byz_count(spec: &str, nodes: usize, band: std::ops::RangeInclusive<usize>) -> u64 {
    (0..10_000u64)
        .find(|&s| {
            ByzantineRoster::from_spec(spec, nodes, s)
                .unwrap()
                .is_some_and(|r| band.contains(&r.count()))
        })
        .expect("a seed with a roster count in band")
}

struct FleetOutcome {
    /// Mean over honest nodes of mean |coordinate - target|.
    honest_err: f64,
    /// Defense accounting summed over honest receivers.
    defense: DefenseStats,
}

/// Run a miniature fleet: every node "trains" by drifting toward a
/// fixed target (plus per-node noise), then broadcasts through its own
/// [`Sharing`] instance and aggregates its neighbors — except that
/// roster-listed adversaries substitute their attack payload for the
/// outgoing model, exactly like the real node loops (their OWN model
/// keeps the honest trajectory; only the wire is corrupted). Flood
/// copies are a transport-level amplification, so this model-level sim
/// delivers one junk row per flooder per round.
fn run_fleet(
    spec: &str,
    byz: &str,
    n: usize,
    neighbors_of: &dyn Fn(usize) -> Vec<usize>,
    rounds: u64,
    dim: usize,
    seed: u64,
) -> FleetOutcome {
    let roster = ByzantineRoster::from_spec(byz, n, seed).unwrap();
    let target: Vec<f32> = (0..dim).map(|j| 0.5 + 0.05 * (j % 8) as f32).collect();
    let mut sharers: Vec<Box<dyn Sharing>> =
        (0..n).map(|i| sharing::from_spec(spec, dim, seed + i as u64).unwrap()).collect();
    let mut rngs: Vec<Xoshiro256pp> =
        (0..n).map(|i| Xoshiro256pp::new(seed ^ (0xF1EE7 + i as u64))).collect();
    let mut models: Vec<ParamVec> = (0..n)
        .map(|i| {
            ParamVec::from_vec(
                target.iter().map(|&t| t + rngs[i].normal_f32(0.0, 0.1)).collect(),
            )
        })
        .collect();
    let mut defense = DefenseStats::default();

    for round in 0..rounds {
        // Honest local step for everyone (adversaries train honestly
        // too; the attack lives at the broadcast boundary).
        for (i, m) in models.iter_mut().enumerate() {
            for (v, &t) in m.as_mut_slice().iter_mut().zip(&target) {
                *v += 0.4 * (t - *v) + rngs[i].normal_f32(0.0, 0.005);
            }
        }
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|i| {
                match roster.as_ref().and_then(|r| r.payload_model(i, round, models[i].as_slice()))
                {
                    Some((attack, _copies)) => {
                        sharers[i].outgoing(&ParamVec::from_vec(attack), round).unwrap()
                    }
                    None => sharers[i].outgoing(&models[i], round).unwrap(),
                }
            })
            .collect();
        let mut next = models.clone();
        for (i, model) in next.iter_mut().enumerate() {
            let nbrs = neighbors_of(i);
            let w = 1.0 / (nbrs.len() + 1) as f64;
            let received: Vec<Received> = nbrs
                .iter()
                .map(|&j| Received { src: j, weight: w, payload: &payloads[j] })
                .collect();
            sharers[i].aggregate(model, w, &received).unwrap();
            if let Some(r) = &roster {
                if !r.is_byzantine(i) {
                    let report = sharers[i].defense_report();
                    for (k, rec) in received.iter().enumerate() {
                        let admitted =
                            report.map_or(1.0, |rep| rep.admitted.get(k).copied().unwrap_or(1.0));
                        defense.observe(r.is_byzantine(rec.src), rec.weight, admitted);
                    }
                }
            }
        }
        models = next;
    }

    let honest: Vec<usize> = (0..n)
        .filter(|&i| !roster.as_ref().is_some_and(|r| r.is_byzantine(i)))
        .collect();
    let honest_err = honest
        .iter()
        .map(|&i| {
            models[i]
                .as_slice()
                .iter()
                .zip(&target)
                .map(|(v, t)| (v - t).abs() as f64)
                .sum::<f64>()
                / dim as f64
        })
        .sum::<f64>()
        / honest.len() as f64;
    FleetOutcome { honest_err, defense }
}

fn complete(n: usize) -> impl Fn(usize) -> Vec<usize> {
    move |i| (0..n).filter(|&j| j != i).collect()
}

fn ring(n: usize, half_degree: usize) -> impl Fn(usize) -> Vec<usize> {
    move |i| (1..=half_degree).flat_map(|d| [(i + d) % n, (i + n - d) % n]).collect()
}

#[test]
fn robust_aggregation_survives_poisoning_where_full_collapses() {
    // 20 fully-connected nodes, 3-5 of them sending 8x-negated models.
    // Every robust rule must keep the honest fleet within 2% (absolute
    // per-coordinate error) of its own honest-run trajectory — the
    // artifact-free proxy for the accuracy acceptance criterion — while
    // isolating >80% of the poisoned contributions. Plain averaging
    // must visibly collapse on the same roster.
    let (n, rounds, dim) = (20usize, 15u64, 16usize);
    let byz = "byzantine:0.2:poison:8";
    let seed = seed_with_byz_count(byz, n, 3..=5);
    let nbrs = complete(n);

    // trim 0.3 * 20 rows = 6 per side >= the pinned 5-adversary worst
    // case; krum:5 likewise tolerates the whole band.
    for spec in ["trimmed_mean:0.3", "coord_median", "krum:5"] {
        let base = run_fleet(spec, "", n, &nbrs, rounds, dim, seed);
        let pois = run_fleet(spec, byz, n, &nbrs, rounds, dim, seed);
        assert!(base.honest_err < 0.05, "{spec}: honest baseline err {}", base.honest_err);
        assert!(
            (pois.honest_err - base.honest_err).abs() <= 0.02,
            "{spec}: poisoned err {} vs honest {}",
            pois.honest_err,
            base.honest_err
        );
        assert!(
            pois.defense.isolation_rate() > 0.8,
            "{spec}: isolation {}",
            pois.defense.isolation_rate()
        );
        assert!(
            pois.defense.poisoned_mass < 0.5,
            "{spec}: admitted poisoned mass {}",
            pois.defense.poisoned_mass
        );
    }

    let full_base = run_fleet("full", "", n, &nbrs, rounds, dim, seed);
    let full_pois = run_fleet("full", byz, n, &nbrs, rounds, dim, seed);
    assert!(full_base.honest_err < 0.05, "full baseline err {}", full_base.honest_err);
    assert!(
        full_pois.honest_err > 0.3,
        "full under poison should collapse: err {}",
        full_pois.honest_err
    );
    // No defense report => everything admitted at weight: the metric
    // itself distinguishes the undefended run.
    assert_eq!(full_pois.defense.isolation_rate(), 0.0);
    assert!(
        full_pois.defense.poisoned_mass > 10.0,
        "full admitted mass {}",
        full_pois.defense.poisoned_mass
    );
}

#[test]
fn flood_junk_is_isolated_and_admitted_mass_bounded() {
    // Flooders broadcast high-variance junk. At the model level the
    // robust rules must reject it (the honest trajectory is unmoved and
    // the admitted Byzantine mass stays under 10% of full admission).
    let (n, rounds, dim) = (20usize, 15u64, 16usize);
    let byz = "byzantine:0.2:flood:4";
    let seed = seed_with_byz_count(byz, n, 3..=5);
    let nbrs = complete(n);
    let w = 1.0 / n as f64;

    for spec in ["trimmed_mean:0.3", "coord_median"] {
        let base = run_fleet(spec, "", n, &nbrs, rounds, dim, seed);
        let flood = run_fleet(spec, byz, n, &nbrs, rounds, dim, seed);
        assert!(
            (flood.honest_err - base.honest_err).abs() <= 0.02,
            "{spec}: flooded err {} vs honest {}",
            flood.honest_err,
            base.honest_err
        );
        assert!(
            flood.defense.isolation_rate() > 0.8,
            "{spec}: isolation {}",
            flood.defense.isolation_rate()
        );
        // Full admission would contribute w per Byzantine contribution.
        let full_admission = w * flood.defense.byz_contribs as f64;
        assert!(
            flood.defense.poisoned_mass < 0.1 * full_admission,
            "{spec}: admitted mass {} vs full admission {}",
            flood.defense.poisoned_mass,
            full_admission
        );
    }
}

#[test]
fn smoke_256_node_poisoned_fleet_reports_nonzero_isolation() {
    // The CI byzantine-smoke target: 256 nodes on a degree-6 ring,
    // ~51 poisoners, trimmed_mean:0.2 — artifact-free and fast. The
    // guarantee asserted here is deliberately the weak one the metric
    // pipeline owes us (nonzero isolation, bounded admitted mass), not
    // full protection: with trim=1 a node with two Byzantine neighbors
    // legitimately admits one of them.
    let (n, rounds, dim) = (256usize, 5u64, 8usize);
    let byz = "byzantine:0.2:poison:8";
    let seed = seed_with_byz_count(byz, n, 40..=65);
    let out = run_fleet("trimmed_mean:0.2", byz, n, &ring(n, 3), rounds, dim, seed);
    assert!(out.defense.byz_contribs > 0, "no Byzantine contributions observed");
    assert!(out.defense.rejected > 0, "no contributions rejected");
    assert!(
        out.defense.isolation_rate() > 0.2,
        "isolation rate {}",
        out.defense.isolation_rate()
    );
    let full_admission = out.defense.byz_contribs as f64 / 7.0;
    assert!(
        out.defense.poisoned_mass < 0.5 * full_admission,
        "admitted mass {} vs full admission {}",
        out.defense.poisoned_mass,
        full_admission
    );
}

// ---------------------------------------------------------------------
// Scheduler-level skeleton: attack traffic is deterministic across
// worker counts, and flood amplification is exactly `factor`.
// ---------------------------------------------------------------------

fn enc(vals: &[f32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// The DL round loop reduced to its scheduler skeleton, with the real
/// roster injected at the real point (the post-train broadcast): train
/// for `step_s`, substitute the attack payload + send `copies`
/// envelopes per peer, then await one model per peer per round,
/// dropping duplicate (src, round) deliveries like the real inboxes.
struct ByzRoundNode {
    id: usize,
    peers: Vec<usize>,
    roster: std::sync::Arc<ByzantineRoster>,
    rounds: u64,
    step_s: f64,
    round: u64,
    waiting: bool,
    have: HashSet<(usize, u64)>,
    dup_drops: u64,
    checksum: u64,
    finished: bool,
}

impl ByzRoundNode {
    fn start_round(&mut self, ctx: &mut NodeCtx) {
        if self.round == self.rounds {
            self.finished = true;
            return;
        }
        self.waiting = false;
        ctx.start_compute(self.step_s, Box::new(|| Ok(ComputeOutput::Value(0.0))));
    }

    fn try_advance(&mut self, ctx: &mut NodeCtx) {
        if self.waiting && self.peers.iter().all(|&p| self.have.contains(&(p, self.round))) {
            self.round += 1;
            self.start_round(ctx);
        }
    }
}

impl EventNode for ByzRoundNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => self.start_round(ctx),
            Wake::ComputeDone(_) => {
                // A deterministic round-dependent "model" keeps honest
                // payload bits meaningful without an engine.
                let model: Vec<f32> =
                    (0..8).map(|j| 1.0 + 0.1 * self.round as f32 + 0.01 * j as f32).collect();
                let (payload, copies) = match self.roster.payload_model(
                    self.id,
                    self.round,
                    &model,
                ) {
                    Some((attack, copies)) => (enc(&attack), copies),
                    None => (enc(&model), 1),
                };
                for &p in &self.peers {
                    for _ in 0..copies {
                        ctx.send(Envelope {
                            src: self.id,
                            dst: p,
                            round: self.round,
                            kind: MsgKind::Model,
                            sent_at_s: 0.0,
                            trace: 0,
                            payload: payload.clone().into(),
                        });
                    }
                }
                self.waiting = true;
                self.try_advance(ctx);
            }
            Wake::Message(m) => {
                // Order-independent content fingerprint: any payload or
                // roster divergence across worker counts changes it.
                let mut h = (m.src as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ m.round;
                for &b in m.payload.as_slice() {
                    h = h.wrapping_mul(31).wrapping_add(b as u64);
                }
                self.checksum = self.checksum.wrapping_add(h);
                if !self.have.insert((m.src, m.round)) {
                    self.dup_drops += 1;
                }
                self.try_advance(ctx);
            }
            Wake::Timer(_) => {}
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.finished
    }
}

#[test]
fn attack_traffic_bit_identical_across_worker_counts() {
    // 32 ring-coupled nodes, a quarter of them flooding 3 copies: per-
    // node virtual end times, receive counters, duplicate-drop counts,
    // and payload-content checksums must be identical for 1/4/8 workers
    // — and total duplicate drops must equal exactly
    // count * peers * (factor - 1) * rounds (amplification is bounded
    // by the factor, nothing more, nothing less).
    let (n, rounds, factor) = (32usize, 4u64, 3u32);
    let byz = "byzantine:0.25:flood:3";
    let seed = seed_with_byz_count(byz, n, 6..=10);
    let roster =
        std::sync::Arc::new(ByzantineRoster::from_spec(byz, n, seed).unwrap().unwrap());

    let run = |workers: usize| -> (Vec<f64>, Vec<u64>, Vec<u64>, u64) {
        let net = decentralize_rs::communication::shaper::NetworkModel {
            latency_s: 0.002,
            bandwidth_bps: 1e7,
        };
        let mut s = Scheduler::new(Some(net), workers);
        let traces: Vec<std::sync::Arc<std::sync::Mutex<(u64, u64, u64)>>> =
            (0..n).map(|_| Default::default()).collect();
        struct Reporting {
            inner: ByzRoundNode,
            out: std::sync::Arc<std::sync::Mutex<(u64, u64, u64)>>,
        }
        impl EventNode for Reporting {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
                self.inner.on_event(ctx, wake)?;
                let mut t = self.out.lock().unwrap();
                *t = (self.inner.checksum, self.inner.dup_drops, self.inner.round);
                Ok(())
            }
            fn done(&self) -> bool {
                self.inner.done()
            }
        }
        for i in 0..n {
            s.add_node(Box::new(Reporting {
                inner: ByzRoundNode {
                    id: i,
                    peers: vec![(i + 1) % n, (i + n - 1) % n],
                    roster: std::sync::Arc::clone(&roster),
                    rounds,
                    step_s: 0.01,
                    round: 0,
                    waiting: false,
                    have: HashSet::new(),
                    dup_drops: 0,
                    checksum: 0,
                    finished: false,
                },
                out: std::sync::Arc::clone(&traces[i]),
            }));
        }
        s.run().unwrap();
        let times: Vec<f64> = (0..n).map(|i| s.node_time(i)).collect();
        let recv: Vec<u64> = (0..n).map(|i| s.counters(i).msgs_recv).collect();
        let sums: Vec<u64> = traces.iter().map(|t| t.lock().unwrap().0).collect();
        let dups: u64 = traces.iter().map(|t| t.lock().unwrap().1).sum();
        (times, recv, sums, dups)
    };

    let (t1, r1, c1, d1) = run(1);
    let (t4, r4, c4, d4) = run(4);
    let (t8, r8, c8, d8) = run(8);
    assert_eq!(t1, t4, "virtual times differ between 1 and 4 workers");
    assert_eq!(t4, t8, "virtual times differ between 4 and 8 workers");
    assert_eq!(r1, r4);
    assert_eq!(r4, r8);
    assert_eq!(c1, c4, "payload checksums differ between 1 and 4 workers");
    assert_eq!(c4, c8, "payload checksums differ between 4 and 8 workers");
    assert_eq!(d1, d4);
    assert_eq!(d4, d8);
    let expected = roster.count() as u64 * 2 * (factor as u64 - 1) * rounds;
    assert_eq!(d1, expected, "flood amplification must be exactly the factor");
}

// ---------------------------------------------------------------------
// Engine-gated full-fidelity runs (skip without compiled artifacts).
// ---------------------------------------------------------------------

/// Artifact/PJRT gate, as in `dl_integration.rs`.
fn engine_or_skip(models: &[&str]) -> Option<EngineHandle> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    match EngineHandle::start(&dir, models) {
        Ok(engine) => Some(engine),
        Err(e) => {
            eprintln!("skipping: PJRT engine unavailable ({e:#})");
            None
        }
    }
}

fn byz_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = name.into();
    cfg.nodes = 8;
    cfg.rounds = 12;
    cfg.eval_every = 6;
    cfg.train_total = 640;
    cfg.test_total = 96;
    cfg.topology = "regular:4".into();
    cfg.local_steps = 2;
    cfg
}

#[test]
fn poisoned_training_with_trimmed_mean_recovers_honest_accuracy() {
    // The acceptance criterion end-to-end: one 8x-poisoner among 8
    // nodes. trimmed_mean:0.2 (trim 1 of 5 rows at degree 4) must land
    // within 2 accuracy points of its own honest run; plain averaging
    // must lose at least 10 points against its honest run.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let byz = "byzantine:0.15:poison:8";
    let mut honest_tm = byz_cfg("it_byz_honest_tm");
    honest_tm.sharing = "trimmed_mean:0.2".into();
    honest_tm.seed = (0..10_000u64)
        .find(|&s| {
            ByzantineRoster::from_spec(byz, honest_tm.nodes, s)
                .unwrap()
                .is_some_and(|r| r.count() == 1)
        })
        .expect("a seed with exactly one adversary");

    let mut pois_tm = honest_tm.clone();
    pois_tm.name = "it_byz_pois_tm".into();
    pois_tm.byzantine = byz.into();
    let mut honest_full = honest_tm.clone();
    honest_full.name = "it_byz_honest_full".into();
    honest_full.sharing = "full".into();
    let mut pois_full = honest_full.clone();
    pois_full.name = "it_byz_pois_full".into();
    pois_full.byzantine = byz.into();

    let r_honest_tm = run_experiment(&honest_tm, &engine).unwrap();
    let r_pois_tm = run_experiment(&pois_tm, &engine).unwrap();
    let r_honest_full = run_experiment(&honest_full, &engine).unwrap();
    let r_pois_full = run_experiment(&pois_full, &engine).unwrap();

    let (a_htm, a_ptm) = (r_honest_tm.final_accuracy(), r_pois_tm.final_accuracy());
    let (a_hf, a_pf) = (r_honest_full.final_accuracy(), r_pois_full.final_accuracy());
    assert!(
        a_ptm >= a_htm - 0.02,
        "trimmed_mean under poison {a_ptm} vs honest {a_htm}"
    );
    assert!(a_pf <= a_hf - 0.10, "full under poison {a_pf} vs honest {a_hf} (no degradation?)");

    // Defense metrics flowed through the records: somebody adjacent to
    // the poisoner rejected it outright, and the robust run admitted
    // strictly less poisoned mass than the undefended one.
    let max_isolation = r_pois_tm
        .logs
        .iter()
        .filter_map(|l| l.records.last())
        .map(|r| r.isolation_rate)
        .fold(0.0f64, f64::max);
    assert!(max_isolation > 0.5, "max isolation {max_isolation}");
    let mass = |r: &decentralize_rs::coordinator::RunResult| -> f64 {
        r.logs.iter().filter_map(|l| l.records.last()).map(|x| x.poisoned_mass_admitted).sum()
    };
    assert!(
        mass(&r_pois_tm) < mass(&r_pois_full),
        "robust admitted mass {} vs full {}",
        mass(&r_pois_tm),
        mass(&r_pois_full)
    );
    engine.shutdown();
}

#[test]
fn byzantine_training_run_bit_identical_across_worker_counts() {
    // The determinism contract extended to adversaries: one prepare(),
    // three worker counts, identical per-node records — including the
    // defense metrics, which would drift first if attack payloads ever
    // depended on event interleaving.
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };
    let mut cfg = byz_cfg("it_byz_workers");
    cfg.sharing = "trimmed_mean:0.2".into();
    cfg.byzantine = "byzantine:0.25:poison:4".into();
    cfg.seed = (0..10_000u64)
        .find(|&s| {
            ByzantineRoster::from_spec(&cfg.byzantine, cfg.nodes, s)
                .unwrap()
                .is_some_and(|r| r.count() >= 1)
        })
        .expect("a seed with at least one adversary");
    let setup = prepare(&cfg, &engine).expect("prepare");
    let mut runs = Vec::new();
    for workers in [1usize, 4, 8] {
        let mut logs = SchedulerRunner { workers }
            .run(&cfg, &engine, &setup, &RunHooks::default())
            .expect("scheduler run")
            .logs;
        logs.sort_by_key(|l| l.node);
        runs.push(logs);
    }
    for other in &runs[1..] {
        assert_eq!(runs[0].len(), other.len());
        for (a, b) in runs[0].iter().zip(other.iter()) {
            assert_eq!(a.records.len(), b.records.len(), "node {}", a.node);
            for (x, y) in a.records.iter().zip(b.records.iter()) {
                assert_eq!(x.test_acc, y.test_acc, "node {}", a.node);
                assert_eq!(x.bytes_sent, y.bytes_sent, "node {}", a.node);
                assert_eq!(
                    x.poisoned_mass_admitted, y.poisoned_mass_admitted,
                    "node {}",
                    a.node
                );
                assert_eq!(x.rejected_contribs, y.rejected_contribs, "node {}", a.node);
                assert_eq!(x.isolation_rate, y.isolation_rate, "node {}", a.node);
            }
        }
    }
    engine.shutdown();
}
