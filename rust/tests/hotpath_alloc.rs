//! Allocation freeze for the round hot path: after a warm-up round, a
//! node's [`Scratch`] arena must never grow again, and the dense
//! aggregation fold must perform literally zero heap allocations.
//!
//! The whole check lives in ONE `#[test]` on purpose: the counting
//! global allocator is process-wide, and a second concurrently-running
//! test would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use decentralize_rs::kernels::fold::FoldCtx;
use decentralize_rs::kernels::Scratch;
use decentralize_rs::model::ParamVec;
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::sharing::{self, Received, Sharing};

/// System allocator wrapper counting every alloc/realloc call.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

const DIM: usize = 4096;
const NEIGHBORS: usize = 6;
// The robust strategies (trimmed_mean / coord_median / krum) are held
// to the same zero-alloc bar: their candidate matrix, per-coordinate
// gather column, admitted counts, and Krum's distance matrix all live
// in existing Scratch buffers (values / mags / doubles), and the sorts
// are `sort_unstable*` (no temp buffer).
const SPECS: [&str; 9] = [
    "full",
    "full:fp16",
    "subsample:0.2",
    "topk:0.2",
    "quant:64",
    "choco:0.2:0.5",
    "trimmed_mean:0.2",
    "coord_median",
    "krum:1",
];

fn rand_model(seed: u64) -> ParamVec {
    let mut rng = Xoshiro256pp::new(seed);
    ParamVec::random(DIM, 1.0, &mut rng)
}

#[test]
fn steady_state_rounds_do_not_allocate_hot_path_buffers() {
    let w = 1.0 / (NEIGHBORS + 1) as f64;
    let self_w = 1.0 - NEIGHBORS as f64 * w;
    let init = ParamVec::zeros(DIM);

    for spec in SPECS {
        // A receiver plus NEIGHBORS senders, each its own instance with
        // its own arena, evolving models — a miniature real fleet.
        let mut receiver = sharing::from_spec(spec, DIM, 0).unwrap();
        receiver.set_init(&init);
        let mut scratch = Scratch::new();
        let mut model = rand_model(1);
        let mut senders: Vec<(Box<dyn Sharing>, ParamVec, Scratch)> = (0..NEIGHBORS)
            .map(|s| {
                let mut sh = sharing::from_spec(spec, DIM, 10 + s as u64).unwrap();
                sh.set_init(&init);
                (sh, rand_model(20 + s as u64), Scratch::new())
            })
            .collect();
        let mut drift = Xoshiro256pp::new(99);
        let mut warm_sig = None;
        for round in 0..12u64 {
            let payloads: Vec<Vec<u8>> = senders
                .iter_mut()
                .map(|(sh, m, sc)| sh.outgoing_with(m, round, sc).unwrap())
                .collect();
            // Pooled broadcast path: the payload buffer is checked out
            // of the arena's pool, refilled in place, and retained for
            // the next round once this handle drops.
            let own_payload = receiver.outgoing_pooled(&model, round, &mut scratch).unwrap();
            drop(own_payload);
            let received: Vec<Received> = payloads
                .iter()
                .enumerate()
                .map(|(s, p)| Received { src: s, weight: w, payload: p })
                .collect();
            receiver
                .aggregate_with(&mut model, self_w, &received, &mut scratch)
                .unwrap();
            // Warm-up is round 0; from round 1 on, the arena's capacity
            // signature must be frozen.
            match warm_sig {
                None => warm_sig = Some(scratch.capacity_signature()),
                Some(sig) => assert_eq!(
                    scratch.capacity_signature(),
                    sig,
                    "{spec}: scratch arena grew after warm-up (round {round})"
                ),
            }
            // Models drift between rounds as in real training.
            for v in model.as_mut_slice().iter_mut() {
                *v += drift.normal_f32(0.0, 0.05);
            }
            for (_, m, _) in senders.iter_mut() {
                for v in m.as_mut_slice().iter_mut() {
                    *v += drift.normal_f32(0.0, 0.05);
                }
            }
        }
    }

    // Part 2: once warm, aggregation performs ZERO heap allocations for
    // every strategy (the payloads are fixed here so the measurement
    // isolates the aggregation path itself).
    for spec in SPECS {
        let payloads: Vec<Vec<u8>> = (0..NEIGHBORS)
            .map(|s| {
                let mut sh = sharing::from_spec(spec, DIM, 30 + s as u64).unwrap();
                sh.set_init(&init);
                sh.outgoing(&rand_model(40 + s as u64), 0).unwrap()
            })
            .collect();
        let received: Vec<Received> = payloads
            .iter()
            .enumerate()
            .map(|(s, p)| Received { src: s, weight: w, payload: p })
            .collect();
        let mut sh = sharing::from_spec(spec, DIM, 0).unwrap();
        sh.set_init(&init);
        let mut model = rand_model(2);
        let mut scratch = Scratch::new();
        for _ in 0..3 {
            sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();
        }
        let before = allocs();
        for _ in 0..25 {
            sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();
        }
        let grew = allocs() - before;
        assert_eq!(grew, 0, "{spec}: {grew} allocations in 25 warm aggregations");
    }

    // Part 3: a warm *pooled* outgoing allocates NOTHING — the payload
    // buffer is checked out of the scratch pool, refilled in place
    // (every encoder reserves its worst case up front, pinning the
    // capacity), and retained for the next round. This is what took
    // the broadcast from one allocation per round to zero. subsample
    // is exempt: its `sample_k` draws a fresh SparseVec by design.
    for spec in [
        "full",
        "full:fp16",
        "topk:0.2",
        "quant:64",
        "choco:0.2:0.5",
        "trimmed_mean:0.2",
        "coord_median",
        "krum:1",
    ] {
        let mut sh = sharing::from_spec(spec, DIM, 0).unwrap();
        sh.set_init(&init);
        let model = rand_model(3);
        let mut scratch = Scratch::new();
        for round in 0..3u64 {
            drop(sh.outgoing_pooled(&model, round, &mut scratch).unwrap());
        }
        let before = allocs();
        let payload = sh.outgoing_pooled(&model, 3, &mut scratch).unwrap();
        let grew = allocs() - before;
        drop(payload);
        assert_eq!(grew, 0, "{spec}: warm pooled outgoing must not allocate ({grew} allocs)");
    }

    // Part 4: tree folds are staged through arena-owned `FoldPartial`
    // accumulators, so a `tree:<width>` plan is held to the same bar as
    // the serial chain — zero allocations once warm, frozen capacity
    // signature. width 2 over 6 neighbors ⇒ 3 groups ⇒ 2 staged
    // partials (group 0 folds straight into the model), the deepest
    // staging any strategy does at this degree; workers = 1 keeps the
    // whole fold on this thread so the counter only sees the hot path.
    for spec in SPECS {
        let payloads: Vec<Vec<u8>> = (0..NEIGHBORS)
            .map(|s| {
                let mut sh = sharing::from_spec(spec, DIM, 50 + s as u64).unwrap();
                sh.set_init(&init);
                sh.outgoing(&rand_model(60 + s as u64), 0).unwrap()
            })
            .collect();
        let received: Vec<Received> = payloads
            .iter()
            .enumerate()
            .map(|(s, p)| Received { src: s, weight: w, payload: p })
            .collect();
        let mut sh = sharing::from_spec(spec, DIM, 0).unwrap();
        sh.set_init(&init);
        sh.set_fold(FoldCtx::tree(2, 1));
        let mut model = rand_model(4);
        let mut scratch = Scratch::new();
        for _ in 0..3 {
            sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();
        }
        let sig = scratch.capacity_signature();
        let before = allocs();
        for _ in 0..25 {
            sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();
        }
        let grew = allocs() - before;
        assert_eq!(grew, 0, "{spec}: {grew} allocations in 25 warm tree:2 fold aggregations");
        assert_eq!(
            scratch.capacity_signature(),
            sig,
            "{spec}: scratch arena grew during warm tree:2 folds"
        );
    }
}
