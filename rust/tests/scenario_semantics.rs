//! Scenario-subsystem semantics, artifact-free: per-link matrices
//! reproduce the per-sender model when uniform, stragglers delay their
//! neighbors' await states in virtual time, departed nodes' in-flight
//! deliveries are dropped, and a 256-node heterogeneous WAN run is
//! deterministic across worker counts. (Training-level scenario runs
//! need compiled artifacts and live in `dl_integration.rs`.)

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use decentralize_rs::communication::shaper::{LinkMatrix, LinkModel, NetworkModel};
use decentralize_rs::communication::{wire_size, Envelope, MsgKind};
use decentralize_rs::scenario::ComputePlan;
use decentralize_rs::scheduler::{ComputeOutput, EventNode, NodeCtx, Scheduler, Wake};

type Trace = Arc<Mutex<Vec<(f64, usize, u64)>>>;

fn env(src: usize, dst: usize, round: u64, len: usize) -> Envelope {
    Envelope {
        src,
        dst,
        round,
        kind: MsgKind::Model,
        sent_at_s: 0.0,
        trace: 0,
        payload: vec![7; len].into(),
    }
}

/// Sends a burst of messages (given payload sizes) to `dst` at t = 0.
struct Blaster {
    id: usize,
    dst: usize,
    sizes: Vec<usize>,
}

impl EventNode for Blaster {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Start = wake {
            for (r, &len) in self.sizes.iter().enumerate() {
                ctx.send(env(self.id, self.dst, r as u64, len));
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

/// Records (arrival virtual time, src, round) for every message.
struct Collector {
    trace: Trace,
    expect: usize,
    got: usize,
}

impl EventNode for Collector {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Message(env) = wake {
            self.trace.lock().unwrap().push((ctx.now_s, env.src, env.round));
            self.got += 1;
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.got >= self.expect
    }
}

fn net() -> NetworkModel {
    NetworkModel { latency_s: 0.01, bandwidth_bps: 1000.0 }
}

/// Run two senders into one collector and return the arrival trace.
fn two_sender_trace(links: Option<LinkModel>) -> Vec<(f64, usize, u64)> {
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::with_links(links, 2);
    s.add_node(Box::new(Blaster { id: 0, dst: 2, sizes: vec![100; 10] }));
    s.add_node(Box::new(Blaster { id: 1, dst: 2, sizes: (0..10).map(|i| 20 + i * 40).collect() }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 20, got: 0 }));
    s.run().unwrap();
    let out = trace.lock().unwrap().clone();
    out
}

#[test]
fn uniform_matrix_reproduces_per_sender_model() {
    // Acceptance: a per-link matrix whose rows are all identical must be
    // bit-identical to the old single NetworkModel path.
    let uniform = two_sender_trace(Some(LinkModel::Uniform(net())));
    let matrix = two_sender_trace(Some(LinkModel::Matrix(Arc::new(LinkMatrix::uniform(3, net())))));
    assert_eq!(uniform, matrix);
}

#[test]
fn per_link_latency_reorders_arrivals() {
    // Same payloads, but node 0's link to the collector is 0.5 s away
    // while node 1's is 1 ms: node 1's whole burst lands first even
    // though node 0 staged earlier.
    let mut m = LinkMatrix::uniform(3, net());
    m.set(0, 2, 0.5, 1e9);
    m.set(1, 2, 0.001, 1e9);
    let trace = two_sender_trace(Some(LinkModel::Matrix(Arc::new(m))));
    assert_eq!(trace.len(), 20);
    let first_ten: Vec<usize> = trace.iter().take(10).map(|t| t.1).collect();
    assert_eq!(first_ten, vec![1; 10], "near link should win: {trace:?}");
    // Per-sender FIFO survives the reordering.
    for src in [0usize, 1] {
        let rounds: Vec<u64> = trace.iter().filter(|t| t.1 == src).map(|t| t.2).collect();
        assert_eq!(rounds, (0..10).collect::<Vec<u64>>(), "sender {src} out of order");
    }
}

/// A round-coupled node: compute for `step_s`, send to `send_to`, then
/// wait for the inbound peer's message of the same round — the
/// scheduler-level skeleton of the DL Train → Broadcast → AwaitModels
/// loop.
struct RoundNode {
    id: usize,
    send_to: usize,
    rounds: u64,
    step_s: f64,
    round: u64,
    waiting: bool,
    have: HashSet<u64>,
    finished: bool,
}

impl RoundNode {
    fn new(id: usize, send_to: usize, rounds: u64, step_s: f64) -> RoundNode {
        RoundNode {
            id,
            send_to,
            rounds,
            step_s,
            round: 0,
            waiting: false,
            have: HashSet::new(),
            finished: false,
        }
    }

    fn start_round(&mut self, ctx: &mut NodeCtx) {
        if self.round == self.rounds {
            self.finished = true;
            return;
        }
        self.waiting = false;
        ctx.start_compute(self.step_s, Box::new(|| Ok(ComputeOutput::Value(0.0))));
    }

    fn try_advance(&mut self, ctx: &mut NodeCtx) {
        if self.waiting && self.have.remove(&self.round) {
            self.round += 1;
            self.start_round(ctx);
        }
    }
}

impl EventNode for RoundNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => self.start_round(ctx),
            Wake::ComputeDone(_) => {
                ctx.send(env(self.id, self.send_to, self.round, 64));
                self.waiting = true;
                self.try_advance(ctx);
            }
            Wake::Message(m) => {
                self.have.insert(m.round);
                self.try_advance(ctx);
            }
            Wake::Timer(_) => {}
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.finished
    }
}

#[test]
fn straggler_delays_neighbor_await_completion() {
    // Two coupled nodes exchanging one model per round. Alone, node 0
    // would finish 5 rounds in ~0.5 s of virtual time; coupled to a 4x
    // straggler it can only complete each AwaitModels when the
    // straggler's model arrives, so its clock stretches to ~2 s.
    let fast_net = NetworkModel { latency_s: 0.0, bandwidth_bps: 1e12 };
    let run = |slow_mult: f64| -> f64 {
        let mut s = Scheduler::new(Some(fast_net), 2);
        s.add_node(Box::new(RoundNode::new(0, 1, 5, 0.1)));
        s.add_node(Box::new(RoundNode::new(1, 0, 5, 0.1 * slow_mult)));
        s.run().unwrap();
        s.node_time(0)
    };
    let balanced = run(1.0);
    let straggled = run(4.0);
    assert!((balanced - 0.5).abs() < 1e-3, "balanced {balanced}");
    assert!((straggled - 2.0).abs() < 1e-3, "straggled {straggled}");
}

/// Departs immediately on start.
struct Leaver;

impl EventNode for Leaver {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Start = wake {
            ctx.depart();
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

#[test]
fn departed_node_drops_in_flight_deliveries() {
    // The leaver departs at t = 0; the burst is timestamped strictly
    // later by the network model, so every delivery pops after the
    // departure and is dropped instead of waking the node.
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(Leaver));
    s.add_node(Box::new(Blaster { id: 1, dst: 0, sizes: vec![100; 5] }));
    s.run().unwrap();
    assert_eq!(s.dropped_deliveries(), 5);
    assert_eq!(s.counters(0).msgs_recv, 0);
    assert_eq!(s.counters(1).msgs_sent, 5); // sends still count as sent
}

/// Departs after seeing `limit` messages.
struct DepartAfter {
    limit: u64,
    seen: u64,
}

impl EventNode for DepartAfter {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Message(_) = wake {
            self.seen += 1;
            if self.seen == self.limit {
                ctx.depart();
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

#[test]
fn departure_mid_stream_drops_only_later_deliveries() {
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(DepartAfter { limit: 2, seen: 0 }));
    s.add_node(Box::new(Blaster { id: 1, dst: 0, sizes: vec![100; 5] }));
    s.run().unwrap();
    assert_eq!(s.counters(0).msgs_recv, 2);
    assert_eq!(s.dropped_deliveries(), 3);
}

/// The acceptance-scale run: 256 ring-coupled nodes with straggler
/// multipliers and a geo-clustered link matrix, bit-identical across
/// worker counts (the determinism contract extended to scenarios).
fn ring_run(workers: usize) -> Vec<f64> {
    let n = 256usize;
    let rounds = 3u64;
    let plan = ComputePlan::from_spec("stragglers:0.2:8", n, 42).unwrap();
    let links = LinkModel::Matrix(Arc::new(LinkMatrix::geo_clustered(n, 8, 42)));
    let mut s = Scheduler::with_links(Some(links), workers);
    for i in 0..n {
        // Each node sends to its right neighbor and awaits its left.
        s.add_node(Box::new(RoundNode::new(i, (i + 1) % n, rounds, 0.01 * plan.multiplier(i))));
    }
    s.run().unwrap();
    (0..n).map(|i| s.node_time(i)).collect()
}

#[test]
fn heterogeneous_wan_run_at_256_nodes_is_deterministic() {
    let a = ring_run(2);
    let b = ring_run(8);
    assert_eq!(a, b, "virtual times depend on worker count");
    // Sanity: heterogeneity actually shows up — not all nodes finish at
    // the same instant, and everyone takes at least 3 compute rounds.
    let min = a.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = a.iter().cloned().fold(0.0f64, f64::max);
    assert!(max > min, "no spread in completion times");
    assert!(min >= 0.0299, "min completion {min}");
}

// ---------------------------------------------------------------------
// LinkMatrix edge cases: self-loops, zero-latency links, asymmetry.
// ---------------------------------------------------------------------

#[test]
fn link_matrix_self_loop_links_are_representable() {
    // A self-loop link (src == dst) is storable and retrievable like any
    // other; the scheduler simply delivers such a message back to its
    // sender under the link's parameters.
    let mut m = LinkMatrix::uniform(3, net());
    m.set(1, 1, 0.25, 400.0);
    assert_eq!(m.link(1, 1), (0.25, 400.0));
    assert!(!m.is_uniform());
    // And the scheduler actually routes a self-addressed message.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::with_links(Some(LinkModel::Matrix(Arc::new(m))), 1);
    struct SelfSender {
        trace: Trace,
        got: bool,
    }
    impl EventNode for SelfSender {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
            match wake {
                Wake::Start => ctx.send(env(0, 0, 0, 100)),
                Wake::Message(_) => {
                    self.trace.lock().unwrap().push((ctx.now_s, 0, 0));
                    self.got = true;
                }
                _ => {}
            }
            Ok(())
        }
        fn done(&self) -> bool {
            self.got
        }
    }
    s.add_node(Box::new(SelfSender { trace: Arc::clone(&trace), got: false }));
    s.run().unwrap();
    let t = trace.lock().unwrap();
    assert_eq!(t.len(), 1);
    // transfer (wire bytes / 400 B/s) + 0.25 s latency.
    let expect = wire_size(&env(0, 0, 0, 100)) as f64 / 400.0 + 0.25;
    assert!((t[0].0 - expect).abs() < 1e-9, "{} vs {expect}", t[0].0);
}

#[test]
fn link_matrix_zero_latency_links_cost_only_transfer_time() {
    let mut m = LinkMatrix::uniform(2, net());
    m.set(0, 1, 0.0, 1000.0);
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::with_links(Some(LinkModel::Matrix(Arc::new(m))), 1);
    s.add_node(Box::new(Blaster { id: 0, dst: 1, sizes: vec![100] }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 1, got: 0 }));
    s.run().unwrap();
    let t = trace.lock().unwrap();
    let expect = wire_size(&env(0, 1, 0, 100)) as f64 / 1000.0;
    assert!((t[0].0 - expect).abs() < 1e-12, "{} vs {expect}", t[0].0);
}

#[test]
fn link_matrix_asymmetric_directions_apply_per_direction() {
    // 0 -> 1 is fast, 1 -> 0 is slow: the same payload takes different
    // virtual times depending on direction.
    let mut m = LinkMatrix::uniform(2, net());
    m.set(0, 1, 0.001, 1e9);
    m.set(1, 0, 0.5, 1e9);
    assert_ne!(m.link(0, 1), m.link(1, 0));
    let run_dir = |src: usize, dst: usize, m: LinkMatrix| -> f64 {
        let trace: Trace = Arc::new(Mutex::new(Vec::new()));
        let mut s = Scheduler::with_links(Some(LinkModel::Matrix(Arc::new(m))), 1);
        let mut nodes: Vec<Box<dyn EventNode>> = vec![
            Box::new(Blaster { id: 0, dst, sizes: if src == 0 { vec![64] } else { vec![] } }),
            Box::new(Blaster { id: 1, dst, sizes: if src == 1 { vec![64] } else { vec![] } }),
            Box::new(Collector { trace: Arc::clone(&trace), expect: 1, got: 0 }),
        ];
        // Replace the destination slot with the collector.
        nodes.swap(dst, 2);
        for n in nodes {
            s.add_node(n);
        }
        s.run().unwrap();
        let t = trace.lock().unwrap();
        t[0].0
    };
    let fast = run_dir(0, 1, m.clone());
    let slow = run_dir(1, 0, m);
    assert!(fast < 0.01, "fast direction {fast}");
    assert!(slow > 0.5, "slow direction {slow}");
}

// ---------------------------------------------------------------------
// Scheduler::dropped_deliveries accounting.
// ---------------------------------------------------------------------

#[test]
fn dropped_deliveries_counts_only_post_departure_messages() {
    // 5 messages spread over virtual time; the receiver departs after
    // the 2nd. Exactly 3 drops, and the counter equals msgs_sent minus
    // msgs_recv (no message is double-counted or lost untracked).
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(DepartAfter { limit: 2, seen: 0 }));
    s.add_node(Box::new(Blaster { id: 1, dst: 0, sizes: vec![100; 5] }));
    s.run().unwrap();
    assert_eq!(s.dropped_deliveries(), 3);
    assert_eq!(
        s.counters(1).msgs_sent - s.counters(0).msgs_recv,
        s.dropped_deliveries()
    );
    // Byte counters never record the dropped deliveries at the receiver.
    assert_eq!(s.counters(0).msgs_recv, 2);
}

#[test]
fn dropped_deliveries_stays_zero_without_departures() {
    let mut s = Scheduler::new(Some(net()), 2);
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    s.add_node(Box::new(Blaster { id: 0, dst: 1, sizes: vec![50; 8] }));
    s.add_node(Box::new(Collector { trace, expect: 8, got: 0 }));
    s.run().unwrap();
    assert_eq!(s.dropped_deliveries(), 0);
}

#[test]
fn dropped_deliveries_counts_crashed_destination() {
    // A crashed node is indistinguishable from a departed one at the
    // delivery layer: everything in flight to it is dropped + counted.
    let mut s = Scheduler::new(Some(net()), 1);
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    s.add_node(Box::new(Collector { trace, expect: 0, got: 0 }));
    s.add_node(Box::new(Blaster { id: 1, dst: 0, sizes: vec![100; 4] }));
    s.set_crash_time(0, 0.0);
    s.run().unwrap();
    assert_eq!(s.dropped_deliveries(), 4);
    assert_eq!(s.counters(0).msgs_recv, 0);
}

// ---------------------------------------------------------------------
// Async-gossip skeleton: deadline-driven rounds tolerate crashes and
// stay deterministic across worker counts.
// ---------------------------------------------------------------------

/// Scheduler-level skeleton of `AsyncDlNodeSm`: train for `step_s`,
/// broadcast, aggregate whatever arrived when the deadline fires, next
/// round. Never waits for any specific neighbor.
struct AsyncSkeleton {
    id: usize,
    peers: Vec<usize>,
    rounds: u64,
    step_s: f64,
    deadline_s: f64,
    round: u64,
    timer: Option<u64>,
    trained: bool,
    deadline_passed: bool,
    inbox: usize,
}

impl AsyncSkeleton {
    fn new(id: usize, peers: Vec<usize>, rounds: u64, step_s: f64, deadline_s: f64) -> AsyncSkeleton {
        AsyncSkeleton {
            id,
            peers,
            rounds,
            step_s,
            deadline_s,
            round: 0,
            timer: None,
            trained: false,
            deadline_passed: false,
            inbox: 0,
        }
    }

    fn begin_round(&mut self, ctx: &mut NodeCtx) {
        if self.round == self.rounds {
            return;
        }
        self.trained = false;
        self.deadline_passed = false;
        self.timer = Some(ctx.set_timer(self.deadline_s));
        ctx.start_compute(self.step_s, Box::new(|| Ok(ComputeOutput::Value(0.0))));
    }

    fn maybe_aggregate(&mut self, ctx: &mut NodeCtx) {
        if !(self.trained && self.deadline_passed) {
            return;
        }
        self.inbox = 0;
        self.round += 1;
        self.begin_round(ctx);
    }
}

impl EventNode for AsyncSkeleton {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => self.begin_round(ctx),
            Wake::ComputeDone(_) => {
                for &p in &self.peers {
                    ctx.send(env(self.id, p, self.round, 64));
                }
                self.trained = true;
                self.maybe_aggregate(ctx);
            }
            Wake::Timer(id) => {
                if self.timer == Some(id) {
                    self.timer = None;
                    self.deadline_passed = true;
                    self.maybe_aggregate(ctx);
                }
            }
            Wake::Message(_) => self.inbox += 1,
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.round == self.rounds
    }
}

/// 16 async-skeleton nodes on a ring; panics if the run deadlocks.
fn async_ring(workers: usize, crash: Option<(usize, f64)>) {
    let n = 16usize;
    let rounds = 4u64;
    let fast = NetworkModel { latency_s: 0.001, bandwidth_bps: 1e9 };
    let mut s = Scheduler::new(Some(fast), workers);
    for i in 0..n {
        let peers = vec![(i + 1) % n, (i + n - 1) % n];
        s.add_node(Box::new(AsyncSkeleton::new(i, peers, rounds, 0.05, 0.2)));
    }
    if let Some((node, at)) = crash {
        s.set_crash_time(node, at);
    }
    s.run().unwrap();
}

#[test]
fn async_deadline_rounds_complete_without_any_neighbor() {
    // A lone async node with unreachable peers still finishes all its
    // rounds, pacing on its deadline (0.2 s/round), never deadlocking.
    let fast = NetworkModel { latency_s: 0.001, bandwidth_bps: 1e9 };
    let mut s = Scheduler::new(Some(fast), 1);
    s.add_node(Box::new(AsyncSkeleton::new(0, vec![1], 3, 0.05, 0.2)));
    // Peer 1 exists but crashes immediately: it never sends anything.
    s.add_node(Box::new(AsyncSkeleton::new(1, vec![0], 3, 0.05, 0.2)));
    s.set_crash_time(1, 0.0);
    s.run().unwrap();
    assert!((s.node_time(0) - 0.6).abs() < 1e-9, "paced at deadlines: {}", s.node_time(0));
    assert!(s.dropped_deliveries() >= 3, "sends to the crashed peer drop");
}

#[test]
fn async_ring_crash_mid_round_never_deadlocks_neighbors() {
    // Node 5 dies at t = 0.27 — mid-round-2 for everyone. Its neighbors
    // time out at their deadlines and the whole run completes.
    async_ring(2, Some((5, 0.27)));
}

#[test]
fn async_ring_deterministic_across_worker_counts() {
    // Virtual end times are bit-identical for 1 / 4 / 8 workers, with
    // and without a crash.
    let end_times = |workers: usize, crash: Option<(usize, f64)>| -> Vec<f64> {
        let n = 16usize;
        let fast = NetworkModel { latency_s: 0.001, bandwidth_bps: 1e9 };
        let mut s = Scheduler::new(Some(fast), workers);
        for i in 0..n {
            let peers = vec![(i + 1) % n, (i + n - 1) % n];
            s.add_node(Box::new(AsyncSkeleton::new(i, peers, 4, 0.05, 0.2)));
        }
        if let Some((node, at)) = crash {
            s.set_crash_time(node, at);
        }
        s.run().unwrap();
        (0..n).map(|i| s.node_time(i)).collect()
    };
    let a = end_times(1, None);
    let b = end_times(4, None);
    let c = end_times(8, None);
    assert_eq!(a, b);
    assert_eq!(b, c);
    let ac = end_times(1, Some((5, 0.27)));
    let bc = end_times(4, Some((5, 0.27)));
    let cc = end_times(8, Some((5, 0.27)));
    assert_eq!(ac, bc);
    assert_eq!(bc, cc);
}
