//! Scenario-subsystem semantics, artifact-free: per-link matrices
//! reproduce the per-sender model when uniform, stragglers delay their
//! neighbors' await states in virtual time, departed nodes' in-flight
//! deliveries are dropped, and a 256-node heterogeneous WAN run is
//! deterministic across worker counts. (Training-level scenario runs
//! need compiled artifacts and live in `dl_integration.rs`.)

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use decentralize_rs::communication::shaper::{LinkMatrix, LinkModel, NetworkModel};
use decentralize_rs::communication::{Envelope, MsgKind};
use decentralize_rs::scenario::ComputePlan;
use decentralize_rs::scheduler::{ComputeOutput, EventNode, NodeCtx, Scheduler, Wake};

type Trace = Arc<Mutex<Vec<(f64, usize, u64)>>>;

fn env(src: usize, dst: usize, round: u64, len: usize) -> Envelope {
    Envelope { src, dst, round, kind: MsgKind::Model, payload: vec![7; len] }
}

/// Sends a burst of messages (given payload sizes) to `dst` at t = 0.
struct Blaster {
    id: usize,
    dst: usize,
    sizes: Vec<usize>,
}

impl EventNode for Blaster {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Start = wake {
            for (r, &len) in self.sizes.iter().enumerate() {
                ctx.send(env(self.id, self.dst, r as u64, len));
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

/// Records (arrival virtual time, src, round) for every message.
struct Collector {
    trace: Trace,
    expect: usize,
    got: usize,
}

impl EventNode for Collector {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Message(env) = wake {
            self.trace.lock().unwrap().push((ctx.now_s, env.src, env.round));
            self.got += 1;
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.got >= self.expect
    }
}

fn net() -> NetworkModel {
    NetworkModel { latency_s: 0.01, bandwidth_bps: 1000.0 }
}

/// Run two senders into one collector and return the arrival trace.
fn two_sender_trace(links: Option<LinkModel>) -> Vec<(f64, usize, u64)> {
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::with_links(links, 2);
    s.add_node(Box::new(Blaster { id: 0, dst: 2, sizes: vec![100; 10] }));
    s.add_node(Box::new(Blaster { id: 1, dst: 2, sizes: (0..10).map(|i| 20 + i * 40).collect() }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 20, got: 0 }));
    s.run().unwrap();
    let out = trace.lock().unwrap().clone();
    out
}

#[test]
fn uniform_matrix_reproduces_per_sender_model() {
    // Acceptance: a per-link matrix whose rows are all identical must be
    // bit-identical to the old single NetworkModel path.
    let uniform = two_sender_trace(Some(LinkModel::Uniform(net())));
    let matrix = two_sender_trace(Some(LinkModel::Matrix(Arc::new(LinkMatrix::uniform(3, net())))));
    assert_eq!(uniform, matrix);
}

#[test]
fn per_link_latency_reorders_arrivals() {
    // Same payloads, but node 0's link to the collector is 0.5 s away
    // while node 1's is 1 ms: node 1's whole burst lands first even
    // though node 0 staged earlier.
    let mut m = LinkMatrix::uniform(3, net());
    m.set(0, 2, 0.5, 1e9);
    m.set(1, 2, 0.001, 1e9);
    let trace = two_sender_trace(Some(LinkModel::Matrix(Arc::new(m))));
    assert_eq!(trace.len(), 20);
    let first_ten: Vec<usize> = trace.iter().take(10).map(|t| t.1).collect();
    assert_eq!(first_ten, vec![1; 10], "near link should win: {trace:?}");
    // Per-sender FIFO survives the reordering.
    for src in [0usize, 1] {
        let rounds: Vec<u64> = trace.iter().filter(|t| t.1 == src).map(|t| t.2).collect();
        assert_eq!(rounds, (0..10).collect::<Vec<u64>>(), "sender {src} out of order");
    }
}

/// A round-coupled node: compute for `step_s`, send to `send_to`, then
/// wait for the inbound peer's message of the same round — the
/// scheduler-level skeleton of the DL Train → Broadcast → AwaitModels
/// loop.
struct RoundNode {
    id: usize,
    send_to: usize,
    rounds: u64,
    step_s: f64,
    round: u64,
    waiting: bool,
    have: HashSet<u64>,
    finished: bool,
}

impl RoundNode {
    fn new(id: usize, send_to: usize, rounds: u64, step_s: f64) -> RoundNode {
        RoundNode {
            id,
            send_to,
            rounds,
            step_s,
            round: 0,
            waiting: false,
            have: HashSet::new(),
            finished: false,
        }
    }

    fn start_round(&mut self, ctx: &mut NodeCtx) {
        if self.round == self.rounds {
            self.finished = true;
            return;
        }
        self.waiting = false;
        ctx.start_compute(self.step_s, Box::new(|| Ok(ComputeOutput::Value(0.0))));
    }

    fn try_advance(&mut self, ctx: &mut NodeCtx) {
        if self.waiting && self.have.remove(&self.round) {
            self.round += 1;
            self.start_round(ctx);
        }
    }
}

impl EventNode for RoundNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => self.start_round(ctx),
            Wake::ComputeDone(_) => {
                ctx.send(env(self.id, self.send_to, self.round, 64));
                self.waiting = true;
                self.try_advance(ctx);
            }
            Wake::Message(m) => {
                self.have.insert(m.round);
                self.try_advance(ctx);
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.finished
    }
}

#[test]
fn straggler_delays_neighbor_await_completion() {
    // Two coupled nodes exchanging one model per round. Alone, node 0
    // would finish 5 rounds in ~0.5 s of virtual time; coupled to a 4x
    // straggler it can only complete each AwaitModels when the
    // straggler's model arrives, so its clock stretches to ~2 s.
    let fast_net = NetworkModel { latency_s: 0.0, bandwidth_bps: 1e12 };
    let run = |slow_mult: f64| -> f64 {
        let mut s = Scheduler::new(Some(fast_net), 2);
        s.add_node(Box::new(RoundNode::new(0, 1, 5, 0.1)));
        s.add_node(Box::new(RoundNode::new(1, 0, 5, 0.1 * slow_mult)));
        s.run().unwrap();
        s.node_time(0)
    };
    let balanced = run(1.0);
    let straggled = run(4.0);
    assert!((balanced - 0.5).abs() < 1e-3, "balanced {balanced}");
    assert!((straggled - 2.0).abs() < 1e-3, "straggled {straggled}");
}

/// Departs immediately on start.
struct Leaver;

impl EventNode for Leaver {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Start = wake {
            ctx.depart();
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

#[test]
fn departed_node_drops_in_flight_deliveries() {
    // The leaver departs at t = 0; the burst is timestamped strictly
    // later by the network model, so every delivery pops after the
    // departure and is dropped instead of waking the node.
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(Leaver));
    s.add_node(Box::new(Blaster { id: 1, dst: 0, sizes: vec![100; 5] }));
    s.run().unwrap();
    assert_eq!(s.dropped_deliveries(), 5);
    assert_eq!(s.counters(0).msgs_recv, 0);
    assert_eq!(s.counters(1).msgs_sent, 5); // sends still count as sent
}

/// Departs after seeing `limit` messages.
struct DepartAfter {
    limit: u64,
    seen: u64,
}

impl EventNode for DepartAfter {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Message(_) = wake {
            self.seen += 1;
            if self.seen == self.limit {
                ctx.depart();
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

#[test]
fn departure_mid_stream_drops_only_later_deliveries() {
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(DepartAfter { limit: 2, seen: 0 }));
    s.add_node(Box::new(Blaster { id: 1, dst: 0, sizes: vec![100; 5] }));
    s.run().unwrap();
    assert_eq!(s.counters(0).msgs_recv, 2);
    assert_eq!(s.dropped_deliveries(), 3);
}

/// The acceptance-scale run: 256 ring-coupled nodes with straggler
/// multipliers and a geo-clustered link matrix, bit-identical across
/// worker counts (the determinism contract extended to scenarios).
fn ring_run(workers: usize) -> Vec<f64> {
    let n = 256usize;
    let rounds = 3u64;
    let plan = ComputePlan::from_spec("stragglers:0.2:8", n, 42).unwrap();
    let links = LinkModel::Matrix(Arc::new(LinkMatrix::geo_clustered(n, 8, 42)));
    let mut s = Scheduler::with_links(Some(links), workers);
    for i in 0..n {
        // Each node sends to its right neighbor and awaits its left.
        s.add_node(Box::new(RoundNode::new(i, (i + 1) % n, rounds, 0.01 * plan.multiplier(i))));
    }
    s.run().unwrap();
    (0..n).map(|i| s.node_time(i)).collect()
}

#[test]
fn heterogeneous_wan_run_at_256_nodes_is_deterministic() {
    let a = ring_run(2);
    let b = ring_run(8);
    assert_eq!(a, b, "virtual times depend on worker count");
    // Sanity: heterogeneity actually shows up — not all nodes finish at
    // the same instant, and everyone takes at least 3 compute rounds.
    let min = a.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = a.iter().cloned().fold(0.0f64, f64::max);
    assert!(max > min, "no spread in completion times");
    assert!(min >= 0.0299, "min completion {min}");
}
