//! Randomized property tests (proptest is unavailable offline, so these
//! drive invariants with the framework's own deterministic PRNG across
//! many generated cases — failures print the case seed for replay).

use decentralize_rs::communication::{decode_envelope, encode_envelope, Envelope, MsgKind};
use decentralize_rs::compression::{
    decode_indices_best, encode_indices_best, FloatCodec, Fp16, Qsgd, RawF32,
};
use decentralize_rs::dataset::Partition;
use decentralize_rs::graph;
use decentralize_rs::kernels::{self, reference, Scratch};
use decentralize_rs::model::{ParamVec, SparseVec};
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::secure;
use decentralize_rs::sharing::{
    self, aggregate_sparse_absolute, aggregate_sparse_absolute_with, decode_sparse, encode_sparse,
    Received, Sharing,
};
use decentralize_rs::store::{ParamSlot, ParamStore};
use decentralize_rs::util::json::{parse, Json};

const CASES: u64 = 60;

fn rand_vals(rng: &mut Xoshiro256pp, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
}

#[test]
fn prop_random_regular_always_regular_and_connected() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(1000 + case);
        let n = rng.range(6, 80);
        let mut d = rng.range(2, 8.min(n - 1));
        if n * d % 2 == 1 {
            d += 1;
        }
        if d >= n {
            continue;
        }
        let g = graph::random_regular(n, d, &mut rng).unwrap();
        assert!((0..n).all(|v| g.degree(v) == d), "case {case}: n={n} d={d}");
        assert!(graph::is_connected(&g), "case {case}");
        // MH weights on it are doubly stochastic.
        let w = graph::metropolis_hastings(&g);
        for v in 0..n {
            let sum: f64 =
                w.self_weight(v) + w.neighbor_weights(v).map(|(_, x)| x).sum::<f64>();
            assert!((sum - 1.0).abs() < 1e-9, "case {case} node {v}: {sum}");
        }
    }
}

#[test]
fn prop_partitions_disjoint_and_in_range() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(2000 + case);
        let n = rng.range(100, 3000);
        let classes = rng.range(2, 12);
        let nodes = rng.range(2, 24);
        let labels: Vec<u8> = (0..n).map(|_| rng.range(0, classes) as u8).collect();
        let part = match case % 3 {
            0 => Partition::Iid,
            1 => {
                let per_node = 1 + (case % 3) as usize;
                if nodes * per_node > n {
                    continue;
                }
                Partition::Shards { per_node }
            }
            _ => Partition::Dirichlet { alpha: 0.1 + (case as f64 % 10.0) },
        };
        let shards = part.split(&labels, nodes, &mut rng);
        assert_eq!(shards.len(), nodes, "case {case}");
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            for &i in s {
                assert!(i < n, "case {case}");
                assert!(seen.insert(i), "case {case}: duplicate {i}");
            }
        }
    }
}

#[test]
fn prop_codecs_roundtrip_within_tolerance() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(3000 + case);
        let n = rng.range(1, 4000);
        let vals = rand_vals(&mut rng, n, 1.0 + case as f32);
        // Raw: exact.
        assert_eq!(RawF32.decode(&RawF32.encode(&vals), n).unwrap(), vals);
        // Fp16: relative error bounded for normal-range values.
        let dec = Fp16.decode(&Fp16.encode(&vals), n).unwrap();
        for (a, b) in vals.iter().zip(&dec) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-4, "case {case}: {a} vs {b}");
        }
        // QSGD: max error bounded by 2*linf/levels.
        let q = Qsgd::new(128, case);
        let dq = q.decode(&q.encode(&vals), n).unwrap();
        let linf = vals.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in vals.iter().zip(&dq) {
            assert!((a - b).abs() <= 2.0 * linf / 127.0 + 1e-5, "case {case}");
        }
    }
}

#[test]
fn prop_sparse_payload_roundtrip() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(4000 + case);
        let dim = rng.range(1, 60_000);
        let k = rng.range(0, dim.min(3000) + 1);
        let mut idx = rng.sample_indices(dim, k);
        idx.sort_unstable();
        let sv = decentralize_rs::model::SparseVec {
            dim,
            values: rand_vals(&mut rng, k, 2.0),
            indices: idx.iter().map(|&i| i as u32).collect(),
        };
        let enc = encode_sparse(&sv);
        assert_eq!(decode_sparse(&enc, dim).unwrap(), sv, "case {case}");
        // Index-only codec agrees too.
        let ienc = encode_indices_best(&sv.indices, dim);
        assert_eq!(decode_indices_best(&ienc, dim).unwrap(), sv.indices, "case {case}");
    }
}

#[test]
fn prop_envelope_roundtrip() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(5000 + case);
        let env = Envelope {
            src: rng.range(0, 2048),
            dst: rng.range(0, 2048),
            round: rng.next_u64() % 1_000_000,
            kind: MsgKind::from_u8((rng.next_u64() % 7) as u8).unwrap(),
            sent_at_s: rng.next_f64() * 1e4,
            trace: 0,
            payload: (0..rng.range(0, 5000))
                .map(|_| rng.next_u32() as u8)
                .collect::<Vec<u8>>()
                .into(),
        };
        assert_eq!(decode_envelope(&encode_envelope(&env)).unwrap(), env, "case {case}");
    }
}

fn random_json(rng: &mut Xoshiro256pp, depth: usize) -> Json {
    match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_f64() * 2e6).round() / 8.0 - 1e5),
        3 => Json::Str(
            (0..rng.range(0, 12))
                .map(|_| char::from_u32(0x20 + rng.next_u32() % 0x250).unwrap_or('x'))
                .collect(),
        ),
        4 => Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.range(0, 5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    for case in 0..CASES * 3 {
        let mut rng = Xoshiro256pp::new(6000 + case);
        let v = random_json(&mut rng, 3);
        let compact = parse(&v.dump()).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(compact, v, "case {case} (compact)");
        let pretty = parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v, "case {case} (pretty)");
    }
}

#[test]
fn prop_topk_matches_naive_sort() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(7000 + case);
        let n = rng.range(1, 2000);
        let k = rng.range(1, n + 1);
        let v = ParamVec::from_vec(rand_vals(&mut rng, n, 1.0));
        let sv = v.topk(k);
        assert_eq!(sv.nnz(), k, "case {case}");
        // The selected set's min |value| >= the max |value| excluded.
        let selected: std::collections::HashSet<u32> = sv.indices.iter().copied().collect();
        let min_sel = sv.values.iter().map(|x| x.abs()).fold(f32::INFINITY, f32::min);
        let max_excl = v
            .as_slice()
            .iter()
            .enumerate()
            .filter(|(i, _)| !selected.contains(&(*i as u32)))
            .map(|(_, x)| x.abs())
            .fold(0.0f32, f32::max);
        assert!(min_sel >= max_excl, "case {case}: {min_sel} < {max_excl}");
    }
}

#[test]
fn prop_gossip_mixing_preserves_mean_and_contracts() {
    // Full-sharing aggregation over a random connected topology is a
    // doubly-stochastic mixing step: the global mean is invariant and the
    // spread contracts after a few rounds.
    for case in 0..20 {
        let mut rng = Xoshiro256pp::new(8000 + case);
        let n = rng.range(4, 16);
        let mut d = rng.range(2, n.min(6) - 1);
        if n * d % 2 == 1 {
            d += 1;
        }
        if d >= n {
            continue;
        }
        let g = graph::random_regular(n, d, &mut rng).unwrap();
        let w = graph::metropolis_hastings(&g);
        let dim = 64;
        let mut models: Vec<ParamVec> =
            (0..n).map(|_| ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0))).collect();
        let mean0: Vec<f64> = (0..dim)
            .map(|i| models.iter().map(|m| m.as_slice()[i] as f64).sum::<f64>() / n as f64)
            .collect();
        let spread = |models: &[ParamVec]| -> f64 {
            models
                .iter()
                .map(|m| {
                    m.as_slice()
                        .iter()
                        .zip(&mean0)
                        .map(|(a, b)| (*a as f64 - b).powi(2))
                        .sum::<f64>()
                })
                .sum()
        };
        let s0 = spread(&models);
        let mut sharers: Vec<Box<dyn Sharing>> =
            (0..n).map(|_| sharing::from_spec("full", dim, 0).unwrap()).collect();
        for round in 0..8 {
            let payloads: Vec<Vec<u8>> = models
                .iter()
                .zip(sharers.iter_mut())
                .map(|(m, s)| s.outgoing(m, round).unwrap())
                .collect();
            let mut next = models.clone();
            for (i, model) in next.iter_mut().enumerate() {
                let received: Vec<Received> = g
                    .neighbors(i)
                    .map(|j| Received { src: j, weight: w.weight(i, j), payload: &payloads[j] })
                    .collect();
                sharers[i].aggregate(model, w.self_weight(i), &received).unwrap();
            }
            models = next;
        }
        // Mean preserved.
        for i in 0..dim {
            let mean: f64 =
                models.iter().map(|m| m.as_slice()[i] as f64).sum::<f64>() / n as f64;
            assert!((mean - mean0[i]).abs() < 1e-4, "case {case} coord {i}");
        }
        // Spread contracted.
        let s1 = spread(&models);
        assert!(s1 < s0 * 0.7, "case {case}: spread {s0} -> {s1}");
    }
}

#[test]
fn prop_secure_masks_cancel_in_weighted_sum() {
    for case in 0..30 {
        let mut rng = Xoshiro256pp::new(9000 + case);
        let k = rng.range(2, 8);
        let dim = rng.range(16, 512);
        let senders: Vec<usize> = (0..k).collect();
        // Random positive weights.
        let weights: Vec<f32> = (0..k).map(|_| 0.05 + rng.next_f32()).collect();
        let models: Vec<Vec<f32>> =
            (0..k).map(|_| rand_vals(&mut rng, dim, 1.0)).collect();
        let mut agg = vec![0.0f64; dim];
        for (si, &s) in senders.iter().enumerate() {
            let masker = secure::Masker::new(s, 42 + case, 4.0);
            let mask = masker.mask_for(99, case, &senders, 1.0 / weights[si], dim);
            for i in 0..dim {
                agg[i] += weights[si] as f64 * (models[si][i] + mask[i]) as f64;
            }
        }
        for i in 0..dim {
            let want: f64 = (0..k)
                .map(|s| weights[s] as f64 * models[s][i] as f64)
                .sum();
            assert!(
                (agg[i] - want).abs() < 2e-2,
                "case {case} coord {i}: {} vs {want}",
                agg[i]
            );
        }
    }
}

#[test]
fn prop_param_store_cow_read_your_writes_and_isolation() {
    // Random interleavings of take/mutate/put and reads across many
    // handles: every node must always observe exactly its own write
    // history (read-your-writes) and never a neighbor's (isolation),
    // with store accounting consistent throughout. Shadow copies are
    // plain per-node vectors mutated in lockstep.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(11_000 + case);
        let dim = rng.range(1, 300);
        let nodes = rng.range(2, 16);
        let base = rand_vals(&mut rng, dim, 1.0);
        let store = ParamStore::from_vec(base.clone());
        let slots: Vec<_> = (0..nodes).map(|_| store.register()).collect();
        let mut shadow: Vec<Vec<f32>> = vec![base.clone(); nodes];
        let mut writers = std::collections::HashSet::new();
        for op in 0..rng.range(5, 80) {
            let who = rng.range(0, nodes);
            if rng.next_f64() < 0.5 {
                // Write: identical mutation on shard and shadow.
                let at = rng.range(0, dim);
                let delta = rng.normal_f32(0.0, 1.0);
                let mut v = slots[who].take_for_write();
                assert_eq!(v, shadow[who], "case {case} op {op}: take view");
                v[at] += delta;
                shadow[who][at] += delta;
                slots[who].put(v);
                writers.insert(who);
            } else {
                // Read-your-writes without materializing.
                slots[who].with(|v| assert_eq!(v, &shadow[who][..], "case {case} op {op}"));
                assert_eq!(slots[who].materialized(), writers.contains(&who));
            }
        }
        // Final isolation check over every node.
        for (i, slot) in slots.iter().enumerate() {
            assert_eq!(slot.to_vec(), shadow[i], "case {case} node {i}");
        }
        // Accounting: exactly the writers materialized, peak >= resident,
        // and resident = writers × dim × 4.
        let s = store.stats();
        assert_eq!(s.nodes, nodes as u64, "case {case}");
        assert_eq!(s.live_shards, writers.len() as u64, "case {case}");
        assert_eq!(s.materialized_total, writers.len() as u64, "case {case}");
        assert_eq!(s.resident_bytes, (writers.len() * dim * 4) as u64, "case {case}");
        assert!(s.peak_resident_bytes >= s.resident_bytes, "case {case}");
        assert_eq!(s.shared_bytes, (dim * 4) as u64, "case {case}");
    }
}

#[test]
fn prop_param_slot_owned_and_stored_agree() {
    // The ParamSlot abstraction must hand back identical vectors in
    // identical order for both modes under random take/mutate/put/read
    // sequences — the invariant behind shared-vs-owned bit-identity.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(12_000 + case);
        let dim = rng.range(1, 200);
        let base = rand_vals(&mut rng, dim, 1.0);
        let store = ParamStore::from_vec(base.clone());
        let mut owned = ParamSlot::owned(base.clone());
        let mut stored = ParamSlot::stored(store.register());
        for op in 0..rng.range(1, 40) {
            if rng.next_f64() < 0.6 {
                let at = rng.range(0, dim);
                let delta = rng.normal_f32(0.0, 2.0);
                let (mut a, mut b) = (owned.take(), stored.take());
                assert_eq!(a, b, "case {case} op {op}");
                a[at] *= 0.5;
                a[at] += delta;
                b[at] *= 0.5;
                b[at] += delta;
                owned.put(a);
                stored.put(b);
            } else {
                assert_eq!(owned.to_vec(), stored.to_vec(), "case {case} op {op}");
            }
        }
        assert_eq!(owned.to_vec(), stored.to_vec(), "case {case} final");
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Lengths that exercise the kernels' 8-lane chunking: multiples of the
/// chunk width, off-by-one on both sides, and arbitrary tails.
fn edge_len(rng: &mut Xoshiro256pp, case: u64) -> usize {
    match case % 4 {
        0 => rng.range(0, 40) * 8,
        1 => rng.range(0, 40) * 8 + 1,
        2 => rng.range(1, 40) * 8 - 1,
        _ => rng.range(0, 3000),
    }
}

#[test]
fn prop_kernels_bit_identical_to_scalar_reference() {
    // The hard contract behind the fused-kernel refactor: every kernel
    // must produce exactly the bits its retained scalar original
    // produced, across chunk boundaries and odd tails.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(13_000 + case);
        let n = edge_len(&mut rng, case);
        let base = rand_vals(&mut rng, n, 2.0);
        let x = rand_vals(&mut rng, n, 1.0);
        let y = rand_vals(&mut rng, n, 1.0);
        let alpha = rng.normal_f32(0.0, 1.0);
        let payload: Vec<u8> = x.iter().flat_map(|v| v.to_le_bytes()).collect();

        let (mut a, mut b) = (base.clone(), base.clone());
        kernels::scale(&mut a, alpha);
        reference::scale(&mut b, alpha);
        assert_eq!(bits(&a), bits(&b), "scale case {case} n={n}");

        kernels::axpy(&mut a, alpha, &x);
        reference::axpy(&mut b, alpha, &x);
        assert_eq!(bits(&a), bits(&b), "axpy case {case} n={n}");

        kernels::diff_axpy(&mut a, alpha, &x, &y);
        reference::diff_axpy(&mut b, alpha, &x, &y);
        assert_eq!(bits(&a), bits(&b), "diff_axpy case {case} n={n}");

        kernels::decode_le_axpy(&mut a, alpha, &payload).unwrap();
        reference::decode_le_axpy(&mut b, alpha, &payload);
        assert_eq!(bits(&a), bits(&b), "decode_le_axpy case {case} n={n}");

        // Widening secure fold.
        let w = rng.next_f64();
        let mut wa = Vec::new();
        kernels::widen_scale(&mut wa, &base, w);
        let mut wb: Vec<f64> = base.iter().map(|&v| v as f64 * w).collect();
        kernels::decode_le_axpy_widen(&mut wa, w, &payload).unwrap();
        reference::decode_le_axpy_widen(&mut wb, w, &payload);
        assert_eq!(wa, wb, "widen fold case {case} n={n}");
        let (mut na, mut nb) = (vec![0.0f32; n], vec![0.0f32; n]);
        kernels::narrow(&mut na, &wa);
        for (p, q) in nb.iter_mut().zip(wb.iter()) {
            *p = *q as f32;
        }
        assert_eq!(bits(&na), bits(&nb), "narrow case {case} n={n}");

        // Scatter kernels over random sorted support.
        if n > 0 {
            let k = rng.range(0, n.min(200) + 1);
            let mut idx = rng.sample_indices(n, k);
            idx.sort_unstable();
            let indices: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
            let vals = rand_vals(&mut rng, k, 1.0);
            kernels::scatter_axpy(&mut a, alpha, &indices, &vals);
            reference::scatter_axpy(&mut b, alpha, &indices, &vals);
            assert_eq!(bits(&a), bits(&b), "scatter_axpy case {case}");
            kernels::scatter_blend(&mut a, alpha, &indices, &vals, &base);
            reference::scatter_blend(&mut b, alpha, &indices, &vals, &base);
            assert_eq!(bits(&a), bits(&b), "scatter_blend case {case}");
        }
    }
}

#[test]
fn prop_full_aggregate_matches_scalar_reference() {
    // FullSharing on the fused kernels vs the retired scalar path
    // (decode into a fresh vector, then fold), bit for bit.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(15_000 + case);
        let dim = edge_len(&mut rng, case).max(1);
        let k = rng.range(1, 7);
        let w = 1.0 / (k + 1) as f64;
        let self_w = 1.0 - k as f64 * w;
        let payloads: Vec<Vec<u8>> = (0..k)
            .map(|_| RawF32.encode(&rand_vals(&mut rng, dim, 1.0)))
            .collect();
        let received: Vec<Received> = payloads
            .iter()
            .enumerate()
            .map(|(s, p)| Received { src: s, weight: w, payload: p })
            .collect();
        let start = rand_vals(&mut rng, dim, 1.0);

        let mut sh = sharing::from_spec("full", dim, 0).unwrap();
        let mut model = ParamVec::from_vec(start.clone());
        let mut scratch = Scratch::new();
        sh.aggregate_with(&mut model, self_w, &received, &mut scratch).unwrap();

        let mut want = start;
        reference::scale(&mut want, self_w as f32);
        for r in &received {
            reference::decode_le_axpy(&mut want, r.weight as f32, r.payload);
        }
        assert_eq!(bits(model.as_slice()), bits(&want), "case {case} dim={dim} k={k}");
    }
}

#[test]
fn prop_sparse_aggregate_kernel_matches_scalar() {
    // The arena-based sparse absolute aggregation (decode_sparse_into +
    // scatter_blend) vs the retained scalar rule, with one dirty arena
    // reused across every case.
    let mut scratch = Scratch::new();
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(16_000 + case);
        let dim = rng.range(1, 2000);
        let k_nbrs = rng.range(1, 6);
        let start = rand_vals(&mut rng, dim, 1.0);
        let mut svs: Vec<(f64, SparseVec)> = Vec::new();
        for _ in 0..k_nbrs {
            let k = rng.range(0, dim.min(300) + 1);
            let mut idx = rng.sample_indices(dim, k);
            idx.sort_unstable();
            svs.push((
                rng.next_f64() / k_nbrs as f64,
                SparseVec {
                    dim,
                    values: rand_vals(&mut rng, k, 1.0),
                    indices: idx.into_iter().map(|i| i as u32).collect(),
                },
            ));
        }
        let mut a = ParamVec::from_vec(start.clone());
        aggregate_sparse_absolute(&mut a, &svs).unwrap();

        let payloads: Vec<(f64, Vec<u8>)> =
            svs.iter().map(|(w, sv)| (*w, encode_sparse(sv))).collect();
        let received: Vec<Received> = payloads
            .iter()
            .enumerate()
            .map(|(s, (w, p))| Received { src: s, weight: *w, payload: p })
            .collect();
        let mut b = ParamVec::from_vec(start);
        aggregate_sparse_absolute_with(&mut b, &received, &mut scratch).unwrap();
        assert_eq!(bits(a.as_slice()), bits(b.as_slice()), "case {case} dim={dim}");
    }
}

#[test]
fn prop_strategies_bit_identical_under_scratch_reuse() {
    // Every strategy must behave identically whether it runs on a fresh
    // throwaway arena per call (the scratch-less trait wrappers) or one
    // long-lived dirty arena (the node hot path) — over multi-round
    // trajectories with evolving models and real payloads.
    let specs = [
        "full",
        "full:fp16",
        "subsample:0.2",
        "topk:0.2",
        "quant:64",
        "choco:0.2:0.5",
        "trimmed_mean:0.2",
        "coord_median",
        "krum:1",
    ];
    for (si, spec) in specs.iter().enumerate() {
        for case in 0..10u64 {
            let mut rng = Xoshiro256pp::new(17_000 + 100 * si as u64 + case);
            let dim = rng.range(1, 600);
            let init = ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0));
            let mut s1 = sharing::from_spec(spec, dim, 5).unwrap();
            let mut s2 = sharing::from_spec(spec, dim, 5).unwrap();
            let mut nbr = sharing::from_spec(spec, dim, 6).unwrap();
            s1.set_init(&init);
            s2.set_init(&init);
            nbr.set_init(&init);
            let mut scratch = Scratch::new();
            let mut m1 = init.clone();
            let mut m2 = init.clone();
            let mut nbr_model = ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0));
            for round in 0..5u64 {
                let p1 = s1.outgoing(&m1, round).unwrap();
                let p2 = s2.outgoing_with(&m2, round, &mut scratch).unwrap();
                assert_eq!(p1, p2, "{spec} case {case} round {round}: payload");
                let pn = nbr.outgoing(&nbr_model, round).unwrap();
                let recv = [Received { src: 9, weight: 0.5, payload: &pn }];
                s1.aggregate(&mut m1, 0.5, &recv).unwrap();
                s2.aggregate_with(&mut m2, 0.5, &recv, &mut scratch).unwrap();
                assert_eq!(
                    bits(m1.as_slice()),
                    bits(m2.as_slice()),
                    "{spec} case {case} round {round}: model"
                );
                for v in nbr_model.as_mut_slice() {
                    *v += rng.normal_f32(0.0, 0.1);
                }
            }
        }
    }
}

#[test]
fn prop_f16_roundtrip_idempotent() {
    use decentralize_rs::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(10_000 + case);
        for _ in 0..200 {
            let exp = rng.range(0, 8) as i32 - 4;
            let x = rng.normal_f32(0.0, 10.0f32.powi(exp));
            let once = f16_bits_to_f32(f32_to_f16_bits(x));
            let twice = f16_bits_to_f32(f32_to_f16_bits(once));
            assert_eq!(once.to_bits(), twice.to_bits(), "case {case}: x={x}");
        }
    }
}

#[test]
fn prop_paged_store_read_your_writes_and_interning_accounting() {
    // Paged CoW under random interleavings: read-your-writes and
    // isolation exactly as in the unpaged store, plus *content-keyed*
    // accounting — the interner dedupes byte-identical divergent pages
    // store-wide (across nodes AND page indices), so live pages must
    // equal the number of unique divergent page bit patterns in the
    // shadow fleet, not the number of (node, page) divergences.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(18_000 + case);
        let dim = rng.range(2, 300);
        let page = rng.range(1, dim + 2); // page > dim = one-page store
        let nodes = rng.range(2, 10);
        let base = rand_vals(&mut rng, dim, 1.0);
        let store = ParamStore::from_vec_paged(base.clone(), page);
        let slots: Vec<_> = (0..nodes).map(|_| store.register()).collect();
        let mut shadow: Vec<Vec<f32>> = vec![base.clone(); nodes];
        for op in 0..rng.range(5, 60) {
            let who = rng.range(0, nodes);
            match rng.range(0, 3) {
                0 => {
                    // Drift a random coordinate.
                    let at = rng.range(0, dim);
                    let delta = rng.normal_f32(0.0, 1.0);
                    let mut v = slots[who].take_for_write();
                    assert_eq!(v, shadow[who], "case {case} op {op}: take view");
                    v[at] += delta;
                    shadow[who][at] += delta;
                    slots[who].put(v);
                }
                1 => {
                    // Write a coordinate back to its base bits — the
                    // reconvergence path that folds pages into the base.
                    let at = rng.range(0, dim);
                    let mut v = slots[who].take_for_write();
                    v[at] = base[at];
                    shadow[who][at] = base[at];
                    slots[who].put(v);
                }
                _ => {
                    slots[who].with(|v| {
                        assert_eq!(v, &shadow[who][..], "case {case} op {op}")
                    });
                    // Materialized iff some page differs from base bits.
                    assert_eq!(
                        slots[who].materialized(),
                        bits(&shadow[who]) != bits(&base),
                        "case {case} op {op}"
                    );
                }
            }
        }
        // End-state accounting from the shadow fleet, content-keyed.
        let mut unique: std::collections::HashSet<Vec<u32>> = std::collections::HashSet::new();
        let mut live_shards = 0u64;
        for sh in &shadow {
            let mut any = false;
            let mut p = 0;
            while p * page < dim {
                let (lo, hi) = (p * page, ((p + 1) * page).min(dim));
                if bits(&sh[lo..hi]) != bits(&base[lo..hi]) {
                    any = true;
                    unique.insert(bits(&sh[lo..hi]));
                }
                p += 1;
            }
            if any {
                live_shards += 1;
            }
        }
        let s = store.stats();
        assert_eq!(s.page_size, page as u64, "case {case}");
        assert_eq!(s.live_shards, live_shards, "case {case}");
        assert_eq!(s.live_pages, unique.len() as u64, "case {case}");
        let page_bytes: u64 = unique.iter().map(|p| p.len() as u64 * 4).sum();
        assert_eq!(s.page_bytes, page_bytes, "case {case}");
        assert_eq!(s.resident_bytes, page_bytes, "case {case}");
        assert!(s.peak_resident_bytes >= s.resident_bytes, "case {case}");
        // Final isolation over every node.
        for (i, slot) in slots.iter().enumerate() {
            assert_eq!(slot.to_vec(), shadow[i], "case {case} node {i}");
        }
    }
}

#[test]
fn prop_param_slot_modes_agree_bitwise() {
    // owned vs stored-shared vs stored-paged (random page size) driven
    // in lockstep: identical histories must yield bit-identical vectors
    // at every step — the invariant behind `param_store` being a pure
    // memory knob with no numeric surface.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(19_000 + case);
        let dim = rng.range(1, 200);
        let page = rng.range(1, dim + 2);
        let base = rand_vals(&mut rng, dim, 1.0);
        let shared = ParamStore::from_vec(base.clone());
        let paged = ParamStore::from_vec_paged(base.clone(), page);
        let mut slots = vec![
            ParamSlot::owned(base.clone()),
            ParamSlot::stored(shared.register()),
            ParamSlot::stored(paged.register()),
        ];
        for op in 0..rng.range(1, 40) {
            if rng.next_f64() < 0.6 {
                let at = rng.range(0, dim);
                let delta = rng.normal_f32(0.0, 2.0);
                let mut taken: Vec<Vec<f32>> = slots.iter_mut().map(|s| s.take()).collect();
                for v in taken.iter_mut() {
                    v[at] *= 0.5;
                    v[at] += delta;
                }
                assert_eq!(bits(&taken[0]), bits(&taken[1]), "case {case} op {op} (shared)");
                assert_eq!(bits(&taken[0]), bits(&taken[2]), "case {case} op {op} (paged)");
                for (s, v) in slots.iter_mut().zip(taken) {
                    s.put(v);
                }
            } else {
                let views: Vec<Vec<f32>> = slots.iter().map(|s| s.to_vec()).collect();
                assert_eq!(bits(&views[0]), bits(&views[1]), "case {case} op {op} (shared)");
                assert_eq!(bits(&views[0]), bits(&views[2]), "case {case} op {op} (paged)");
            }
        }
    }
}

#[test]
fn prop_paged_interning_reconverges_to_baseline() {
    // Diverge -> reconverge (write the base bits back) -> every byte of
    // page accounting returns to zero while the peak keeps its mark;
    // the store must then support rediverging (the intern table and
    // slot state fully reset, not just the counters).
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(20_000 + case);
        let dim = rng.range(2, 300);
        let page = rng.range(1, dim + 2);
        let nodes = rng.range(1, 8);
        let base = rand_vals(&mut rng, dim, 1.0);
        let store = ParamStore::from_vec_paged(base.clone(), page);
        let slots: Vec<_> = (0..nodes).map(|_| store.register()).collect();
        // Diverge every node at a handful of coordinates (+1.0.. shifts
        // always change the bits of N(0,1) values).
        for slot in &slots {
            let mut v = slot.take_for_write();
            for _ in 0..rng.range(1, 6) {
                let at = rng.range(0, dim);
                v[at] += 1.0 + rng.next_f32();
            }
            slot.put(v);
        }
        let mid = store.stats();
        assert!(mid.live_pages >= 1, "case {case}");
        assert_eq!(mid.live_shards, nodes as u64, "case {case}");
        assert!(mid.resident_bytes > 0, "case {case}");
        // Reconverge: every node writes the base back, bit for bit.
        for slot in &slots {
            let mut v = slot.take_for_write();
            v.copy_from_slice(&base);
            slot.put(v);
        }
        let s = store.stats();
        assert_eq!(s.live_pages, 0, "case {case}");
        assert_eq!(s.page_bytes, 0, "case {case}");
        assert_eq!(s.live_shards, 0, "case {case}");
        assert_eq!(s.resident_bytes, 0, "case {case}");
        assert!(s.peak_resident_bytes >= mid.resident_bytes, "case {case}");
        for slot in &slots {
            assert!(!slot.materialized(), "case {case}: reconverged slot still paged-live");
            slot.with(|v| assert_eq!(bits(v), bits(&base), "case {case}"));
        }
        // Rediverge one node: the drained store is still fully usable.
        let mut v = slots[0].take_for_write();
        v[0] += 3.5;
        slots[0].put(v);
        let s2 = store.stats();
        assert_eq!(s2.live_shards, 1, "case {case}");
        assert!(s2.live_pages >= 1, "case {case}");
    }
}

#[test]
fn prop_robust_kernels_bit_identical_to_scalar_reference() {
    // The robust-aggregation kernels (gathered columns, reused scratch
    // buffers) vs their retained allocating scalar twins: outputs,
    // per-row admitted counts, distance matrices, and Krum picks must
    // all agree exactly, across chunk-edge dims and every legal trim.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(21_000 + case);
        let dim = edge_len(&mut rng, case).max(1);
        let rows = rng.range(1, 9);
        let vals = rand_vals(&mut rng, rows * dim, 2.0);
        // 2*trim < rows must hold; sample the full legal range.
        let trim = rng.range(0, (rows - 1) / 2 + 1);

        let mut out_a = vec![0.0f32; dim];
        let mut out_b = vec![0.0f32; dim];
        let mut gather = vec![0.0f32; 2 * rows];
        let mut adm_a = vec![0.0f64; rows];
        let mut adm_b = vec![0.0f64; rows];
        kernels::trimmed_mean(&mut out_a, &vals, rows, trim, &mut gather, &mut adm_a);
        reference::trimmed_mean(&mut out_b, &vals, rows, trim, &mut adm_b);
        assert_eq!(bits(&out_a), bits(&out_b), "trimmed_mean case {case} rows={rows} trim={trim}");
        assert_eq!(adm_a, adm_b, "trimmed_mean admitted case {case} rows={rows} trim={trim}");

        adm_a.iter_mut().for_each(|v| *v = -1.0);
        adm_b.iter_mut().for_each(|v| *v = -1.0);
        kernels::coord_median(&mut out_a, &vals, rows, &mut gather, &mut adm_a);
        reference::coord_median(&mut out_b, &vals, rows, &mut adm_b);
        assert_eq!(bits(&out_a), bits(&out_b), "coord_median case {case} rows={rows}");
        assert_eq!(adm_a, adm_b, "coord_median admitted case {case} rows={rows}");

        let mut dist = vec![0.0f64; rows * rows];
        kernels::pairwise_sq_dist(&vals, rows, dim, &mut dist);
        let mut dist_ref = vec![0.0f64; rows * rows];
        reference::pairwise_sq_dist(&vals, rows, dim, &mut dist_ref);
        assert_eq!(dist, dist_ref, "pairwise_sq_dist case {case} rows={rows} dim={dim}");
        let closest = rng.range(0, rows);
        let mut row_buf = vec![0.0f64; rows];
        let pick = kernels::krum_select(&dist, rows, closest, &mut row_buf);
        let mut row_ref = vec![0.0f64; rows];
        let pick_ref = reference::krum_select(&dist_ref, rows, closest, &mut row_ref);
        assert_eq!(pick, pick_ref, "krum_select case {case} rows={rows} closest={closest}");
    }
}

#[test]
fn prop_tree_folds_worker_invariant_and_wide_width_degenerates_to_serial() {
    // The fold contract, over every strategy: (1) the reduction-tree
    // shape is a pure function of (degree, width), so one plan yields
    // bit-identical models at ANY worker count; (2) a `width >= degree`
    // tree is a single group and therefore bitwise equal to the serial
    // chain. One dirty arena is shared across every spec × degree ×
    // plan, so the staged `FoldPartial` buffers are always inherited at
    // the wrong size/contents first — the partials-reuse case.
    use decentralize_rs::kernels::fold::FoldCtx;
    let specs = [
        "full",
        "full:fp16",
        "subsample:0.2",
        "topk:0.2",
        "quant:64",
        "choco:0.2:0.5",
        "trimmed_mean:0.2",
        "coord_median",
        "krum:1",
    ];
    let mut scratch = Scratch::new();
    for (si, spec) in specs.iter().enumerate() {
        for (di, &degree) in [16usize, 33, 64].iter().enumerate() {
            for case in 0..2u64 {
                let seed = 23_000 + 1000 * si as u64 + 100 * di as u64 + case;
                let mut rng = Xoshiro256pp::new(seed);
                let dim = rng.range(1, 400);
                let init = ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0));
                let start = ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0));
                let w = 1.0 / (degree + 1) as f64;
                let self_w = 1.0 - degree as f64 * w;
                // Two rounds of payloads from persistent (stateful)
                // per-sender instances with drifting models.
                let mut senders: Vec<(Box<dyn Sharing>, ParamVec)> = (0..degree)
                    .map(|s| {
                        let mut sh = sharing::from_spec(spec, dim, 40 + s as u64).unwrap();
                        sh.set_init(&init);
                        (sh, ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0)))
                    })
                    .collect();
                let mut rounds: Vec<Vec<Vec<u8>>> = Vec::new();
                for round in 0..2u64 {
                    let ps: Vec<Vec<u8>> =
                        senders.iter_mut().map(|(sh, m)| sh.outgoing(m, round).unwrap()).collect();
                    rounds.push(ps);
                    for (_, m) in senders.iter_mut() {
                        for v in m.as_mut_slice() {
                            *v += rng.normal_f32(0.0, 0.1);
                        }
                    }
                }
                // Replay both rounds on a fresh same-seed receiver under
                // one fold plan; return the per-round model bits.
                let run_plan = |fold: FoldCtx, scratch: &mut Scratch| -> Vec<Vec<u32>> {
                    let mut sh = sharing::from_spec(spec, dim, 0).unwrap();
                    sh.set_init(&init);
                    sh.set_fold(fold);
                    let mut model = start.clone();
                    rounds
                        .iter()
                        .map(|payloads| {
                            let received: Vec<Received> = payloads
                                .iter()
                                .enumerate()
                                .map(|(s, p)| Received { src: s, weight: w, payload: p })
                                .collect();
                            sh.aggregate_with(&mut model, self_w, &received, scratch).unwrap();
                            bits(model.as_slice())
                        })
                        .collect()
                };
                let serial = run_plan(FoldCtx::serial(), &mut scratch);
                let wide = run_plan(FoldCtx::tree(degree, 4), &mut scratch);
                assert_eq!(
                    serial,
                    wide,
                    "{spec} deg {degree} case {case}: width >= degree tree must equal serial"
                );
                // A real tree (width 8 < degree) reassociates, but the
                // plan alone fixes the bits: workers 1, 4, 8 agree.
                let w1 = run_plan(FoldCtx::tree(8, 1), &mut scratch);
                let w4 = run_plan(FoldCtx::tree(8, 4), &mut scratch);
                let w8 = run_plan(FoldCtx::tree(8, 8), &mut scratch);
                assert_eq!(w1, w4, "{spec} deg {degree} case {case}: workers 1 vs 4 differ");
                assert_eq!(w1, w8, "{spec} deg {degree} case {case}: workers 1 vs 8 differ");
            }
        }
    }
}

#[test]
fn prop_robust_aggregation_invariant_in_receive_order() {
    // The robust rules canonicalize candidates by sender id before
    // doing anything, so the aggregated model must be bit-identical no
    // matter the order in which the same messages happened to arrive —
    // and the defense report must permute exactly with the caller's
    // received order.
    for (si, spec) in ["trimmed_mean:0.25", "coord_median", "krum:1"].iter().enumerate() {
        for case in 0..CASES / 3 {
            let mut rng = Xoshiro256pp::new(22_000 + 1000 * si as u64 + case);
            let dim = rng.range(1, 400);
            let k = rng.range(1, 8);
            let w = 1.0 / (k + 1) as f64;
            let start = rand_vals(&mut rng, dim, 1.0);
            // Distinct, non-contiguous sender ids.
            let payloads: Vec<(usize, Vec<u8>)> = (0..k)
                .map(|i| (3 * i + 1, RawF32.encode(&rand_vals(&mut rng, dim, 1.0))))
                .collect();
            let ordered: Vec<Received> = payloads
                .iter()
                .map(|(s, p)| Received { src: *s, weight: w, payload: p })
                .collect();
            // Fisher–Yates with the case PRNG: a deterministic shuffle.
            let mut perm: Vec<usize> = (0..k).collect();
            for i in (1..k).rev() {
                let j = rng.range(0, i + 1);
                perm.swap(i, j);
            }
            let shuffled: Vec<Received> = perm
                .iter()
                .map(|&i| Received {
                    src: ordered[i].src,
                    weight: ordered[i].weight,
                    payload: ordered[i].payload,
                })
                .collect();

            let mut s1 = sharing::from_spec(spec, dim, 0).unwrap();
            let mut s2 = sharing::from_spec(spec, dim, 0).unwrap();
            let mut m1 = ParamVec::from_vec(start.clone());
            let mut m2 = ParamVec::from_vec(start.clone());
            let mut scratch = Scratch::new();
            s1.aggregate_with(&mut m1, w, &ordered, &mut scratch).unwrap();
            s2.aggregate_with(&mut m2, w, &shuffled, &mut scratch).unwrap();
            assert_eq!(
                bits(m1.as_slice()),
                bits(m2.as_slice()),
                "{spec} case {case}: model depends on receive order"
            );
            let r1 = s1.defense_report().unwrap();
            let r2 = s2.defense_report().unwrap();
            assert_eq!(r1.admitted.len(), k, "{spec} case {case}");
            assert_eq!(r2.admitted.len(), k, "{spec} case {case}");
            for (pos, &orig) in perm.iter().enumerate() {
                assert_eq!(
                    r1.admitted[orig], r2.admitted[pos],
                    "{spec} case {case}: report did not permute with the received order"
                );
            }
        }
    }
}
