//! Randomized property tests (proptest is unavailable offline, so these
//! drive invariants with the framework's own deterministic PRNG across
//! many generated cases — failures print the case seed for replay).

use decentralize_rs::communication::{decode_envelope, encode_envelope, Envelope, MsgKind};
use decentralize_rs::compression::{
    decode_indices_best, encode_indices_best, FloatCodec, Fp16, Qsgd, RawF32,
};
use decentralize_rs::dataset::Partition;
use decentralize_rs::graph;
use decentralize_rs::model::ParamVec;
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::secure;
use decentralize_rs::sharing::{self, decode_sparse, encode_sparse, Received, Sharing};
use decentralize_rs::store::{ParamSlot, ParamStore};
use decentralize_rs::util::json::{parse, Json};

const CASES: u64 = 60;

fn rand_vals(rng: &mut Xoshiro256pp, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
}

#[test]
fn prop_random_regular_always_regular_and_connected() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(1000 + case);
        let n = rng.range(6, 80);
        let mut d = rng.range(2, 8.min(n - 1));
        if n * d % 2 == 1 {
            d += 1;
        }
        if d >= n {
            continue;
        }
        let g = graph::random_regular(n, d, &mut rng).unwrap();
        assert!((0..n).all(|v| g.degree(v) == d), "case {case}: n={n} d={d}");
        assert!(graph::is_connected(&g), "case {case}");
        // MH weights on it are doubly stochastic.
        let w = graph::metropolis_hastings(&g);
        for v in 0..n {
            let sum: f64 =
                w.self_weight(v) + w.neighbor_weights(v).map(|(_, x)| x).sum::<f64>();
            assert!((sum - 1.0).abs() < 1e-9, "case {case} node {v}: {sum}");
        }
    }
}

#[test]
fn prop_partitions_disjoint_and_in_range() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(2000 + case);
        let n = rng.range(100, 3000);
        let classes = rng.range(2, 12);
        let nodes = rng.range(2, 24);
        let labels: Vec<u8> = (0..n).map(|_| rng.range(0, classes) as u8).collect();
        let part = match case % 3 {
            0 => Partition::Iid,
            1 => {
                let per_node = 1 + (case % 3) as usize;
                if nodes * per_node > n {
                    continue;
                }
                Partition::Shards { per_node }
            }
            _ => Partition::Dirichlet { alpha: 0.1 + (case as f64 % 10.0) },
        };
        let shards = part.split(&labels, nodes, &mut rng);
        assert_eq!(shards.len(), nodes, "case {case}");
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            for &i in s {
                assert!(i < n, "case {case}");
                assert!(seen.insert(i), "case {case}: duplicate {i}");
            }
        }
    }
}

#[test]
fn prop_codecs_roundtrip_within_tolerance() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(3000 + case);
        let n = rng.range(1, 4000);
        let vals = rand_vals(&mut rng, n, 1.0 + case as f32);
        // Raw: exact.
        assert_eq!(RawF32.decode(&RawF32.encode(&vals), n).unwrap(), vals);
        // Fp16: relative error bounded for normal-range values.
        let dec = Fp16.decode(&Fp16.encode(&vals), n).unwrap();
        for (a, b) in vals.iter().zip(&dec) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-4, "case {case}: {a} vs {b}");
        }
        // QSGD: max error bounded by 2*linf/levels.
        let q = Qsgd::new(128, case);
        let dq = q.decode(&q.encode(&vals), n).unwrap();
        let linf = vals.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in vals.iter().zip(&dq) {
            assert!((a - b).abs() <= 2.0 * linf / 127.0 + 1e-5, "case {case}");
        }
    }
}

#[test]
fn prop_sparse_payload_roundtrip() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(4000 + case);
        let dim = rng.range(1, 60_000);
        let k = rng.range(0, dim.min(3000) + 1);
        let mut idx = rng.sample_indices(dim, k);
        idx.sort_unstable();
        let sv = decentralize_rs::model::SparseVec {
            dim,
            values: rand_vals(&mut rng, k, 2.0),
            indices: idx.iter().map(|&i| i as u32).collect(),
        };
        let enc = encode_sparse(&sv);
        assert_eq!(decode_sparse(&enc, dim).unwrap(), sv, "case {case}");
        // Index-only codec agrees too.
        let ienc = encode_indices_best(&sv.indices, dim);
        assert_eq!(decode_indices_best(&ienc, dim).unwrap(), sv.indices, "case {case}");
    }
}

#[test]
fn prop_envelope_roundtrip() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(5000 + case);
        let env = Envelope {
            src: rng.range(0, 2048),
            dst: rng.range(0, 2048),
            round: rng.next_u64() % 1_000_000,
            kind: MsgKind::from_u8((rng.next_u64() % 7) as u8).unwrap(),
            sent_at_s: rng.next_f64() * 1e4,
            payload: (0..rng.range(0, 5000))
                .map(|_| rng.next_u32() as u8)
                .collect::<Vec<u8>>()
                .into(),
        };
        assert_eq!(decode_envelope(&encode_envelope(&env)).unwrap(), env, "case {case}");
    }
}

fn random_json(rng: &mut Xoshiro256pp, depth: usize) -> Json {
    match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_f64() * 2e6).round() / 8.0 - 1e5),
        3 => Json::Str(
            (0..rng.range(0, 12))
                .map(|_| char::from_u32(0x20 + rng.next_u32() % 0x250).unwrap_or('x'))
                .collect(),
        ),
        4 => Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.range(0, 5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    for case in 0..CASES * 3 {
        let mut rng = Xoshiro256pp::new(6000 + case);
        let v = random_json(&mut rng, 3);
        let compact = parse(&v.dump()).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(compact, v, "case {case} (compact)");
        let pretty = parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v, "case {case} (pretty)");
    }
}

#[test]
fn prop_topk_matches_naive_sort() {
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(7000 + case);
        let n = rng.range(1, 2000);
        let k = rng.range(1, n + 1);
        let v = ParamVec::from_vec(rand_vals(&mut rng, n, 1.0));
        let sv = v.topk(k);
        assert_eq!(sv.nnz(), k, "case {case}");
        // The selected set's min |value| >= the max |value| excluded.
        let selected: std::collections::HashSet<u32> = sv.indices.iter().copied().collect();
        let min_sel = sv.values.iter().map(|x| x.abs()).fold(f32::INFINITY, f32::min);
        let max_excl = v
            .as_slice()
            .iter()
            .enumerate()
            .filter(|(i, _)| !selected.contains(&(*i as u32)))
            .map(|(_, x)| x.abs())
            .fold(0.0f32, f32::max);
        assert!(min_sel >= max_excl, "case {case}: {min_sel} < {max_excl}");
    }
}

#[test]
fn prop_gossip_mixing_preserves_mean_and_contracts() {
    // Full-sharing aggregation over a random connected topology is a
    // doubly-stochastic mixing step: the global mean is invariant and the
    // spread contracts after a few rounds.
    for case in 0..20 {
        let mut rng = Xoshiro256pp::new(8000 + case);
        let n = rng.range(4, 16);
        let mut d = rng.range(2, n.min(6) - 1);
        if n * d % 2 == 1 {
            d += 1;
        }
        if d >= n {
            continue;
        }
        let g = graph::random_regular(n, d, &mut rng).unwrap();
        let w = graph::metropolis_hastings(&g);
        let dim = 64;
        let mut models: Vec<ParamVec> =
            (0..n).map(|_| ParamVec::from_vec(rand_vals(&mut rng, dim, 1.0))).collect();
        let mean0: Vec<f64> = (0..dim)
            .map(|i| models.iter().map(|m| m.as_slice()[i] as f64).sum::<f64>() / n as f64)
            .collect();
        let spread = |models: &[ParamVec]| -> f64 {
            models
                .iter()
                .map(|m| {
                    m.as_slice()
                        .iter()
                        .zip(&mean0)
                        .map(|(a, b)| (*a as f64 - b).powi(2))
                        .sum::<f64>()
                })
                .sum()
        };
        let s0 = spread(&models);
        let mut sharers: Vec<Box<dyn Sharing>> =
            (0..n).map(|_| sharing::from_spec("full", dim, 0).unwrap()).collect();
        for round in 0..8 {
            let payloads: Vec<Vec<u8>> = models
                .iter()
                .zip(sharers.iter_mut())
                .map(|(m, s)| s.outgoing(m, round).unwrap())
                .collect();
            let mut next = models.clone();
            for (i, model) in next.iter_mut().enumerate() {
                let received: Vec<Received> = g
                    .neighbors(i)
                    .map(|j| Received { src: j, weight: w.weight(i, j), payload: &payloads[j] })
                    .collect();
                sharers[i].aggregate(model, w.self_weight(i), &received).unwrap();
            }
            models = next;
        }
        // Mean preserved.
        for i in 0..dim {
            let mean: f64 =
                models.iter().map(|m| m.as_slice()[i] as f64).sum::<f64>() / n as f64;
            assert!((mean - mean0[i]).abs() < 1e-4, "case {case} coord {i}");
        }
        // Spread contracted.
        let s1 = spread(&models);
        assert!(s1 < s0 * 0.7, "case {case}: spread {s0} -> {s1}");
    }
}

#[test]
fn prop_secure_masks_cancel_in_weighted_sum() {
    for case in 0..30 {
        let mut rng = Xoshiro256pp::new(9000 + case);
        let k = rng.range(2, 8);
        let dim = rng.range(16, 512);
        let senders: Vec<usize> = (0..k).collect();
        // Random positive weights.
        let weights: Vec<f32> = (0..k).map(|_| 0.05 + rng.next_f32()).collect();
        let models: Vec<Vec<f32>> =
            (0..k).map(|_| rand_vals(&mut rng, dim, 1.0)).collect();
        let mut agg = vec![0.0f64; dim];
        for (si, &s) in senders.iter().enumerate() {
            let masker = secure::Masker::new(s, 42 + case, 4.0);
            let mask = masker.mask_for(99, case, &senders, 1.0 / weights[si], dim);
            for i in 0..dim {
                agg[i] += weights[si] as f64 * (models[si][i] + mask[i]) as f64;
            }
        }
        for i in 0..dim {
            let want: f64 = (0..k)
                .map(|s| weights[s] as f64 * models[s][i] as f64)
                .sum();
            assert!(
                (agg[i] - want).abs() < 2e-2,
                "case {case} coord {i}: {} vs {want}",
                agg[i]
            );
        }
    }
}

#[test]
fn prop_param_store_cow_read_your_writes_and_isolation() {
    // Random interleavings of take/mutate/put and reads across many
    // handles: every node must always observe exactly its own write
    // history (read-your-writes) and never a neighbor's (isolation),
    // with store accounting consistent throughout. Shadow copies are
    // plain per-node vectors mutated in lockstep.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(11_000 + case);
        let dim = rng.range(1, 300);
        let nodes = rng.range(2, 16);
        let base = rand_vals(&mut rng, dim, 1.0);
        let store = ParamStore::from_vec(base.clone());
        let slots: Vec<_> = (0..nodes).map(|_| store.register()).collect();
        let mut shadow: Vec<Vec<f32>> = vec![base.clone(); nodes];
        let mut writers = std::collections::HashSet::new();
        for op in 0..rng.range(5, 80) {
            let who = rng.range(0, nodes);
            if rng.next_f64() < 0.5 {
                // Write: identical mutation on shard and shadow.
                let at = rng.range(0, dim);
                let delta = rng.normal_f32(0.0, 1.0);
                let mut v = slots[who].take_for_write();
                assert_eq!(v, shadow[who], "case {case} op {op}: take view");
                v[at] += delta;
                shadow[who][at] += delta;
                slots[who].put(v);
                writers.insert(who);
            } else {
                // Read-your-writes without materializing.
                slots[who].with(|v| assert_eq!(v, &shadow[who][..], "case {case} op {op}"));
                assert_eq!(slots[who].materialized(), writers.contains(&who));
            }
        }
        // Final isolation check over every node.
        for (i, slot) in slots.iter().enumerate() {
            assert_eq!(slot.to_vec(), shadow[i], "case {case} node {i}");
        }
        // Accounting: exactly the writers materialized, peak >= resident,
        // and resident = writers × dim × 4.
        let s = store.stats();
        assert_eq!(s.nodes, nodes as u64, "case {case}");
        assert_eq!(s.live_shards, writers.len() as u64, "case {case}");
        assert_eq!(s.materialized_total, writers.len() as u64, "case {case}");
        assert_eq!(s.resident_bytes, (writers.len() * dim * 4) as u64, "case {case}");
        assert!(s.peak_resident_bytes >= s.resident_bytes, "case {case}");
        assert_eq!(s.shared_bytes, (dim * 4) as u64, "case {case}");
    }
}

#[test]
fn prop_param_slot_owned_and_stored_agree() {
    // The ParamSlot abstraction must hand back identical vectors in
    // identical order for both modes under random take/mutate/put/read
    // sequences — the invariant behind shared-vs-owned bit-identity.
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(12_000 + case);
        let dim = rng.range(1, 200);
        let base = rand_vals(&mut rng, dim, 1.0);
        let store = ParamStore::from_vec(base.clone());
        let mut owned = ParamSlot::owned(base.clone());
        let mut stored = ParamSlot::stored(store.register());
        for op in 0..rng.range(1, 40) {
            if rng.next_f64() < 0.6 {
                let at = rng.range(0, dim);
                let delta = rng.normal_f32(0.0, 2.0);
                let (mut a, mut b) = (owned.take(), stored.take());
                assert_eq!(a, b, "case {case} op {op}");
                a[at] *= 0.5;
                a[at] += delta;
                b[at] *= 0.5;
                b[at] += delta;
                owned.put(a);
                stored.put(b);
            } else {
                assert_eq!(owned.to_vec(), stored.to_vec(), "case {case} op {op}");
            }
        }
        assert_eq!(owned.to_vec(), stored.to_vec(), "case {case} final");
    }
}

#[test]
fn prop_f16_roundtrip_idempotent() {
    use decentralize_rs::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
    for case in 0..CASES {
        let mut rng = Xoshiro256pp::new(10_000 + case);
        for _ in 0..200 {
            let exp = rng.range(0, 8) as i32 - 4;
            let x = rng.normal_f32(0.0, 10.0f32.powi(exp));
            let once = f16_bits_to_f32(f32_to_f16_bits(x));
            let twice = f16_bits_to_f32(f32_to_f16_bits(once));
            assert_eq!(once.to_bits(), twice.to_bits(), "case {case}: x={x}");
        }
    }
}
