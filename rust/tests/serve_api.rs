//! End-to-end tests for the `decentra serve` daemon: every request in
//! here goes over a real TCP connection against an in-process
//! [`Daemon`] bound to port 0, exercising the hand-rolled HTTP layer,
//! the run queue, cooperative cancellation, SSE streaming, and the
//! Prometheus endpoint together.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use decentralize_rs::metrics::NodeLog;
use decentralize_rs::serve::{Daemon, ServeOptions};
use decentralize_rs::util::json::{parse, Json};

/// An in-process daemon plus the thread its accept loop runs on.
struct TestDaemon {
    addr: SocketAddr,
    thread: std::thread::JoinHandle<anyhow::Result<()>>,
}

fn start_daemon() -> TestDaemon {
    let opts = ServeOptions { addr: "127.0.0.1:0".into(), ..ServeOptions::default() };
    let daemon = Daemon::bind(&opts).expect("bind daemon");
    let addr = daemon.local_addr();
    let thread = std::thread::spawn(move || daemon.run());
    TestDaemon { addr, thread }
}

impl TestDaemon {
    fn shutdown(self) {
        let (code, _) = one_shot(self.addr, "POST", "/shutdown", "");
        assert_eq!(code, 200);
        self.thread.join().expect("daemon thread").expect("daemon run");
    }
}

/// Read one `Content-Length`-framed HTTP response.
fn read_response(stream: &mut TcpStream) -> (u16, String) {
    let mut head = Vec::new();
    let mut buf = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = head.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut buf).expect("read response");
        assert!(n > 0, "connection closed mid-response");
        head.extend_from_slice(&buf[..n]);
    };
    let (header_bytes, rest) = head.split_at(header_end);
    let rest = &rest[4..];
    let text = std::str::from_utf8(header_bytes).expect("response headers are UTF-8");
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let mut content_length = 0usize;
    for line in text.lines().skip(1) {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().expect("content-length");
            }
        }
    }
    let mut body = rest.to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut buf).expect("read body");
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&buf[..n]);
    }
    body.truncate(content_length);
    (status, String::from_utf8_lossy(&body).into_owned())
}

/// Connect, issue one request, return (status, body).
fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("write request");
    read_response(&mut stream)
}

/// Poll `GET /runs/:id` until its status is one of `want`; panics after
/// `timeout`. Returns the final status document.
fn wait_for_status(addr: SocketAddr, id: u64, want: &[&str], timeout: Duration) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let (code, body) = one_shot(addr, "GET", &format!("/runs/{id}"), "");
        assert_eq!(code, 200, "GET /runs/{id}: {body}");
        let doc = parse(&body).expect("status JSON");
        let status = doc.get("status").as_str().unwrap_or("").to_string();
        if want.contains(&status.as_str()) {
            return doc;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for status {want:?} on run {id} (last {status:?})"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Open the SSE stream for a run and read raw bytes until `stop_at`
/// appears (headers included in the returned text).
fn read_sse_until(addr: SocketAddr, id: u64, stop_at: &str, timeout: Duration) -> String {
    read_sse_at(addr, &format!("/runs/{id}/events"), stop_at, timeout)
}

/// Like [`read_sse_until`], but for an explicit path (query included).
fn read_sse_at(addr: SocketAddr, path: &str, stop_at: &str, timeout: Duration) -> String {
    let deadline = Instant::now() + timeout;
    let mut stream = TcpStream::connect(addr).expect("sse connect");
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .expect("sse read timeout");
    let req = format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n");
    stream.write_all(req.as_bytes()).expect("sse request");
    let mut raw = Vec::new();
    let mut buf = [0u8; 16 * 1024];
    loop {
        let text = String::from_utf8_lossy(&raw).into_owned();
        if text.contains(stop_at) {
            return text;
        }
        assert!(Instant::now() < deadline, "SSE stream never produced {stop_at:?}:\n{text}");
        match stream.read(&mut buf) {
            Ok(0) => return String::from_utf8_lossy(&raw).into_owned(),
            Ok(n) => raw.extend_from_slice(&buf[..n]),
            Err(_) => continue, // read timeout: re-check the deadline
        }
    }
}

/// Parse `(event, data)` pairs out of a raw SSE byte stream. Keepalive
/// comments and the HTTP header block carry no `event:`/`data:` lines
/// and fall out naturally.
fn parse_sse(text: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for frame in text.split("\n\n") {
        let mut event = None;
        let mut data = None;
        for line in frame.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = Some(v.to_string());
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = Some(v.to_string());
            }
        }
        if let (Some(e), Some(d)) = (event, data) {
            out.push((e, d));
        }
    }
    out
}

/// A minimal sim-driver config the daemon accepts.
fn sim_config(name: &str, nodes: usize, rounds: u64, eval_every: u64, dir: &Path) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("nodes", Json::num(nodes as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("eval_every", Json::num(eval_every as f64)),
        ("topology", Json::str("ring")),
        ("network", Json::str("none")),
        ("workers", Json::num(2.0)),
        ("train_total", Json::num(nodes.max(2048) as f64)),
        ("results_dir", Json::str(dir.display().to_string())),
    ])
}

fn submit(addr: SocketAddr, body: &Json) -> u64 {
    let (code, body) = one_shot(addr, "POST", "/runs", &body.dump());
    assert_eq!(code, 201, "POST /runs: {body}");
    let doc = parse(&body).expect("submit JSON");
    assert_eq!(doc.get("status").as_str(), Some("queued"));
    doc.get("id").as_f64().expect("run id") as u64
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve_api_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Tentpole acceptance: every `round` event streamed over SSE carries
/// the same record — byte for byte — that the run later saves to
/// `node_*.jsonl`, and the stream terminates with `run_finished` +
/// `end` once the ring closes.
#[test]
fn sse_round_events_match_saved_records_bit_for_bit() {
    let dir = temp_dir("sse");
    let daemon = start_daemon();
    let cfg = sim_config("sse_bitforbit", 4, 6, 2, &dir);
    // Bare config: the daemon defaults to the sim driver.
    let id = submit(daemon.addr, &cfg);

    let text = read_sse_until(daemon.addr, id, "event: end", Duration::from_secs(120));
    let frames = parse_sse(&text);
    assert_eq!(frames.first().map(|(e, _)| e.as_str()), Some("run_started"));
    let started = parse(&frames[0].1).expect("run_started data");
    assert_eq!(started.get("nodes").as_usize(), Some(4));
    assert_eq!(started.get("rounds").as_usize(), Some(6));
    let finished: Vec<_> = frames.iter().filter(|(e, _)| e == "run_finished").collect();
    assert_eq!(finished.len(), 1);
    let fin = parse(&finished[0].1).expect("run_finished data");
    assert_eq!(fin.get("cancelled").as_bool(), Some(false));
    assert_eq!(frames.last().map(|(e, _)| e.as_str()), Some("end"));

    // Group the streamed round payloads per node, preserving order.
    let mut streamed = std::collections::BTreeMap::<usize, Vec<String>>::new();
    for (event, data) in &frames {
        if event == "round" {
            let doc = parse(data).expect("round data");
            let node = doc.get("node").as_usize().expect("round node id");
            streamed.entry(node).or_default().push(doc.get("record").dump());
        }
    }
    // 4 nodes x eval rounds {1, 3, 5}.
    assert_eq!(streamed.len(), 4);
    assert!(streamed.values().all(|v| v.len() == 3), "{streamed:?}");

    // The executor saves after the ring closes; wait for it to land.
    let doc = wait_for_status(daemon.addr, id, &["done"], Duration::from_secs(120));
    let results = PathBuf::from(doc.get("results_dir").as_str().expect("results_dir"));
    let logs = NodeLog::load_dir(&results).expect("saved node logs");
    assert_eq!(logs.len(), 4);
    for log in &logs {
        let saved: Vec<String> = log.records.iter().map(|r| r.to_json().dump()).collect();
        assert_eq!(
            streamed.get(&log.node),
            Some(&saved),
            "node {} streamed records differ from node_{:04}.jsonl",
            log.node,
            log.node
        );
    }

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tentpole acceptance: DELETE on a running 1024-node fleet sets the
/// cooperative cancel flag, the scheduler stops at a round boundary,
/// and the partial logs it saves hold only complete eval-round records.
#[test]
fn cancelled_1024_node_run_stops_at_round_boundary() {
    let dir = temp_dir("cancel");
    let daemon = start_daemon();
    let rounds = 10_000u64;
    let cfg = sim_config("cancelme", 1024, rounds, 5, &dir);
    let envelope = Json::obj(vec![("driver", Json::str("sim")), ("config", cfg)]);
    let id = submit(daemon.addr, &envelope);

    // Wait for live round telemetry so the cancel lands mid-run.
    let text = read_sse_until(daemon.addr, id, "event: round", Duration::from_secs(300));
    assert!(text.contains("event: run_started"));

    let (code, body) = one_shot(daemon.addr, "DELETE", &format!("/runs/{id}"), "");
    assert_eq!(code, 200, "DELETE /runs/{id}: {body}");
    let ack = parse(&body).expect("cancel ack");
    assert_eq!(ack.get("cancel_requested").as_bool(), Some(true));

    let doc = wait_for_status(daemon.addr, id, &["cancelled"], Duration::from_secs(300));
    assert!(doc.get("rounds_streamed").as_f64().unwrap_or(0.0) >= 1.0);

    // The partial results are saved like any finished run's, and every
    // record sits on an eval boundary: nothing mid-round leaks out.
    let results = PathBuf::from(doc.get("results_dir").as_str().expect("results_dir"));
    let logs = NodeLog::load_dir(&results).expect("saved node logs");
    assert_eq!(logs.len(), 1024);
    let mut max_round = 0u64;
    let mut records = 0usize;
    for log in &logs {
        for r in &log.records {
            assert!(
                (r.round + 1) % 5 == 0 || r.round + 1 == rounds,
                "node {} saved a non-boundary round {}",
                log.node,
                r.round
            );
            max_round = max_round.max(r.round);
            records += 1;
        }
    }
    assert!(records >= 1, "cancelled run saved no records at all");
    assert!(max_round < rounds - 1, "run was not actually cut short (max round {max_round})");

    // A second DELETE is a conflict: the run already finished.
    let (code, _) = one_shot(daemon.addr, "DELETE", &format!("/runs/{id}"), "");
    assert_eq!(code, 409);

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// SSE cursor hardening: non-numeric cursors fail fast with a 400, and
/// cursors past the head or issued after the ring closed end cleanly
/// with an `end` frame instead of hanging the connection.
#[test]
fn sse_cursor_edge_cases() {
    let dir = temp_dir("cursor");
    let daemon = start_daemon();
    let addr = daemon.addr;
    let id = submit(addr, &sim_config("cursor", 4, 4, 2, &dir));
    wait_for_status(addr, id, &["done"], Duration::from_secs(120));

    // Non-numeric / negative cursors: a clean client error, not a
    // silent restart from sequence 0.
    let (code, body) = one_shot(addr, "GET", &format!("/runs/{id}/events?from=abc"), "");
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("integer"), "{body}");
    let (code, _) = one_shot(addr, "GET", &format!("/runs/{id}/events?from=-1"), "");
    assert_eq!(code, 400);

    // Resume from 0 after close: full replay, then `end`.
    let path = format!("/runs/{id}/events?from=0");
    let text = read_sse_at(addr, &path, "event: end", Duration::from_secs(60));
    assert!(text.contains("event: run_started"), "{text}");
    assert!(text.contains("event: run_finished"), "{text}");

    // A cursor far past the head on a closed ring: no replay, just a
    // prompt `end` — the reader must not wait for events that will
    // never come.
    let path = format!("/runs/{id}/events?from=1000000");
    let text = read_sse_at(addr, &path, "event: end", Duration::from_secs(60));
    let frames = parse_sse(&text);
    assert_eq!(frames, vec![("end".to_string(), "{}".to_string())], "{text}");

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `GET /runs/:id/trace` serves the Chrome trace for a traced sim run
/// (artifact-free), and the executor folds the run's spans and round
/// statistics into the Prometheus registry.
#[test]
fn trace_endpoint_and_phase_metrics() {
    let dir = temp_dir("trace");
    let daemon = start_daemon();
    let addr = daemon.addr;
    let mut cfg = sim_config("traced", 4, 4, 2, &dir);
    if let Json::Obj(m) = &mut cfg {
        m.insert("trace".into(), Json::str("full"));
    }
    let id = submit(addr, &cfg);
    wait_for_status(addr, id, &["done"], Duration::from_secs(120));

    let (code, body) = one_shot(addr, "GET", &format!("/runs/{id}/trace"), "");
    assert_eq!(code, 200, "{body}");
    let doc = parse(&body).expect("trace JSON");
    let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(events.iter().any(|e| e.get("ph").as_str() == Some("X")), "no spans");
    assert!(events.iter().any(|e| e.get("ph").as_str() == Some("s")), "no flow edges");
    let tracks = events
        .iter()
        .filter(|e| e.get("name").as_str() == Some("thread_name"))
        .count();
    assert_eq!(tracks, 4, "one thread track per node");

    let (code, metrics) = one_shot(addr, "GET", "/metrics", "");
    assert_eq!(code, 200);
    assert!(metrics.contains("decentra_phase_seconds_bucket{phase=\"train\""), "{metrics}");
    assert!(metrics.contains("decentra_phase_seconds_bucket{phase=\"aggregate\""), "{metrics}");
    assert!(metrics.contains("decentra_staleness_seconds_bucket"), "{metrics}");
    assert!(metrics.contains("decentra_round_duration_seconds_count"), "{metrics}");
    assert!(metrics.contains("decentra_telemetry_dropped_events"), "{metrics}");
    assert!(metrics.contains("decentra_telemetry_buffered_events"), "{metrics}");

    // An untraced run has no recorder: the trace endpoint is a 404.
    let plain = submit(addr, &sim_config("untraced", 4, 4, 2, &dir));
    wait_for_status(addr, plain, &["done"], Duration::from_secs(120));
    let (code, body) = one_shot(addr, "GET", &format!("/runs/{plain}/trace"), "");
    assert_eq!(code, 404, "{body}");

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Routing, validation, queue semantics, and the metrics endpoint.
#[test]
fn http_api_end_to_end() {
    let dir = temp_dir("e2e");
    let daemon = start_daemon();
    let addr = daemon.addr;

    let (code, body) = one_shot(addr, "GET", "/healthz", "");
    assert_eq!((code, body.as_str()), (200, "ok\n"));
    let (code, _) = one_shot(addr, "GET", "/nope", "");
    assert_eq!(code, 404);
    let (code, _) = one_shot(addr, "PUT", "/runs", "");
    assert_eq!(code, 405);
    let (code, _) = one_shot(addr, "GET", "/runs/notanumber", "");
    assert_eq!(code, 404);
    let (code, _) = one_shot(addr, "GET", "/runs/999", "");
    assert_eq!(code, 404);

    let (code, body) = one_shot(addr, "POST", "/runs", "{not json");
    assert_eq!(code, 400, "{body}");
    let bogus = Json::obj(vec![
        ("driver", Json::str("bogus")),
        ("config", sim_config("x", 4, 4, 2, &dir)),
    ]);
    let (code, body) = one_shot(addr, "POST", "/runs", &bogus.dump());
    assert_eq!(code, 400, "{body}");
    // Valid config, but an axis the sim driver rejects at submit time.
    let mut async_cfg = sim_config("x", 4, 4, 2, &dir);
    if let Json::Obj(m) = &mut async_cfg {
        m.insert("mode".into(), Json::str("async_dl"));
    }
    let (code, body) = one_shot(addr, "POST", "/runs", &async_cfg.dump());
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("sim driver"), "{body}");

    // Run A occupies the executor; run B stays queued behind it and
    // cancels instantly (its SSE stream just ends).
    let a = submit(addr, &sim_config("e2e_a", 64, 100_000, 5, &dir));
    let b = submit(addr, &sim_config("e2e_b", 64, 100_000, 5, &dir));
    wait_for_status(addr, a, &["running"], Duration::from_secs(120));
    let (code, body) = one_shot(addr, "GET", "/runs", "");
    assert_eq!(code, 200);
    let listing = parse(&body).expect("listing JSON");
    let runs = match listing.get("runs") {
        Json::Arr(rows) => rows.clone(),
        other => panic!("runs is not an array: {other:?}"),
    };
    assert!(runs.len() >= 2);

    let (code, body) = one_shot(addr, "DELETE", &format!("/runs/{b}"), "");
    assert_eq!(code, 200, "{body}");
    let doc = parse(&body).expect("queued-cancel JSON");
    assert_eq!(doc.get("status").as_str(), Some("cancelled"));
    let text = read_sse_until(addr, b, "event: end", Duration::from_secs(60));
    assert!(!text.contains("event: round"), "queued run streamed rounds:\n{text}");

    let (code, _) = one_shot(addr, "DELETE", &format!("/runs/{a}"), "");
    assert_eq!(code, 200);
    wait_for_status(addr, a, &["cancelled"], Duration::from_secs(300));

    let (code, body) = one_shot(addr, "GET", "/metrics", "");
    assert_eq!(code, 200);
    assert!(body.contains("decentra_http_requests_total"), "{body}");
    assert!(body.contains("decentra_runs_submitted_total"), "{body}");
    assert!(body.contains("decentra_runs_cancelled_total"), "{body}");
    assert!(body.contains("decentra_http_request_seconds"), "{body}");

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
