//! End-to-end trace semantics through the artifact-free sim driver.
//!
//! The tentpole guarantee: the **virtual** half of a trace (span
//! starts, durations, flow edges) is a pure function of the config and
//! seed — bit-identical across scheduler worker counts — while the
//! wall-clock fields are free to differ run to run. The exports must
//! match the Chrome trace-event schema and the folded-stack grammar.

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::RunHooks;
use decentralize_rs::serve::run_sim;
use decentralize_rs::trace::{Phase, TraceMode, TraceRecorder, TraceSnapshot};
use decentralize_rs::util::json::parse;

const NODES: usize = 6;

fn traced_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "trace_semantics".into();
    cfg.nodes = NODES;
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg.topology = "ring".into();
    cfg.network = "none".into();
    cfg.workers = workers;
    cfg.trace = "full".into();
    cfg.train_total = 2048;
    cfg
}

/// Run the sim fleet with a full recorder attached and snapshot it.
fn record(workers: usize) -> TraceSnapshot {
    let rec = TraceRecorder::new(TraceMode::Full);
    let hooks = RunHooks { trace: Some(rec.clone()), ..RunHooks::default() };
    run_sim(&traced_cfg(workers), &hooks).unwrap();
    rec.snapshot()
}

#[test]
fn virtual_layout_is_identical_across_worker_counts() {
    let base = record(1);
    assert!(!base.spans.is_empty(), "full tracing must record spans");
    assert!(!base.flows.is_empty(), "gossip hops must pair into flow edges");
    assert_eq!(base.dropped_spans, 0);
    assert_eq!(base.dropped_flows, 0);
    let sig = base.virtual_signature();
    for workers in [4, 8] {
        let other = record(workers);
        assert_eq!(sig, other.virtual_signature(), "layout diverged at {workers} workers");
    }
}

#[test]
fn spans_cover_the_round_phases() {
    let snap = record(2);
    for phase in [Phase::Train, Phase::Encode, Phase::Aggregate, Phase::Deliver] {
        assert!(
            snap.spans.iter().any(|s| s.phase == phase),
            "no {} span recorded",
            phase.name()
        );
    }
}

#[test]
fn flow_edges_connect_send_to_delivery() {
    let snap = record(2);
    for f in &snap.flows {
        assert!(f.recv_virt_s >= f.send_virt_s, "flow {} arrives before it is sent", f.id);
        assert!((f.src as usize) < NODES && (f.dst as usize) < NODES);
        assert_ne!(f.src, f.dst, "ring gossip never self-loops");
    }
    // Every round gossips both directions around the ring.
    assert!(snap.flows.len() >= NODES, "{} flows for {NODES} nodes", snap.flows.len());
}

#[test]
fn chrome_export_matches_the_trace_event_schema() {
    let snap = record(2);
    let v = parse(&snap.to_chrome_json()).unwrap();
    assert_eq!(v.get("displayTimeUnit").as_str(), Some("ms"));
    assert_eq!(v.get("otherData").get("clock").as_str(), Some("virtual"));
    let events = v.get("traceEvents").as_arr().expect("traceEvents array");
    let mut tracks = std::collections::BTreeSet::new();
    let (mut spans, mut starts, mut ends) = (0usize, 0usize, 0usize);
    for ev in events {
        match ev.get("ph").as_str().expect("every event has ph") {
            "M" => {
                if ev.get("name").as_str() == Some("thread_name") {
                    tracks.insert(ev.get("tid").as_f64().unwrap() as u64);
                }
            }
            "X" => {
                spans += 1;
                assert!(ev.get("ts").as_f64().is_some());
                assert!(ev.get("dur").as_f64().is_some());
                assert!(ev.get("args").get("wall_dur_s").as_f64().is_some());
            }
            "s" => starts += 1,
            "f" => {
                ends += 1;
                assert_eq!(ev.get("bp").as_str(), Some("e"));
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert_eq!(tracks.len(), NODES, "one thread track per node");
    assert_eq!(spans, snap.spans.len());
    assert_eq!(starts, snap.flows.len());
    assert_eq!(starts, ends, "every flow start pairs with a finish");
    assert!(starts > 0);
}

#[test]
fn folded_stacks_follow_the_grammar() {
    let snap = record(2);
    let folded = snap.to_folded();
    assert!(!folded.is_empty());
    for line in folded.lines() {
        let (stack, dur) = line.rsplit_once(' ').expect("stack <weight>");
        let _: u64 = dur.parse().expect("integer microsecond weight");
        let parts: Vec<&str> = stack.split(';').collect();
        assert_eq!(parts.len(), 3, "node;round;phase in {line:?}");
        assert!(parts[0].starts_with("node"));
        assert!(parts[1].starts_with("round"));
    }
}

#[test]
fn off_and_sampled_recorders_stay_consistent() {
    // sample:0 never samples; the scheduler still runs to completion.
    let rec = TraceRecorder::new(TraceMode::Sample(0.0));
    let hooks = RunHooks { trace: Some(rec.clone()), ..RunHooks::default() };
    run_sim(&traced_cfg(2), &hooks).unwrap();
    let snap = rec.snapshot();
    assert!(snap.spans.is_empty());
    assert!(snap.flows.is_empty());
}
