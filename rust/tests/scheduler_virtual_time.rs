//! Virtual-time scheduler semantics, artifact-free: deterministic
//! delivery ordering, per-sender FIFO preservation, uplink/latency
//! timestamp math, and independence from worker count / real execution
//! order. (Scheduler-vs-threads training equivalence lives in
//! `dl_integration.rs` — it needs compiled artifacts.)

use std::sync::{Arc, Mutex};

use decentralize_rs::communication::shaper::NetworkModel;
use decentralize_rs::communication::{wire_size, Envelope, MsgKind};
use decentralize_rs::scheduler::{ComputeOutput, EventNode, NodeCtx, Scheduler, Wake};

type Trace = Arc<Mutex<Vec<(f64, usize, u64)>>>;

fn env(src: usize, dst: usize, round: u64, len: usize) -> Envelope {
    Envelope { src, dst, round, kind: MsgKind::Model, sent_at_s: 0.0, payload: vec![7; len].into() }
}

/// Sends a burst of messages (given payload sizes) to `dst` at t = 0.
struct Blaster {
    id: usize,
    dst: usize,
    sizes: Vec<usize>,
}

impl EventNode for Blaster {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Start = wake {
            for (r, &len) in self.sizes.iter().enumerate() {
                ctx.send(env(self.id, self.dst, r as u64, len));
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

/// Records (arrival virtual time, src, round) for every message.
struct Collector {
    trace: Trace,
    expect: usize,
    got: usize,
}

impl EventNode for Collector {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Message(env) = wake {
            self.trace.lock().unwrap().push((ctx.now_s, env.src, env.round));
            self.got += 1;
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.got >= self.expect
    }
}

fn net() -> NetworkModel {
    NetworkModel { latency_s: 0.01, bandwidth_bps: 1000.0 }
}

#[test]
fn delivery_times_follow_uplink_serialization() {
    // One sender, two messages: the second queues behind the first on
    // the sender's uplink; each pays one latency after its transfer.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(Blaster { id: 0, dst: 1, sizes: vec![100, 50] }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 2, got: 0 }));
    s.run().unwrap();
    let w0 = wire_size(&env(0, 1, 0, 100)) as f64;
    let w1 = wire_size(&env(0, 1, 1, 50)) as f64;
    let t0 = w0 / 1000.0 + 0.01;
    let t1 = (w0 + w1) / 1000.0 + 0.01;
    let trace = trace.lock().unwrap();
    assert_eq!(trace.len(), 2);
    assert!((trace[0].0 - t0).abs() < 1e-12, "{} vs {t0}", trace[0].0);
    assert!((trace[1].0 - t1).abs() < 1e-12, "{} vs {t1}", trace[1].0);
}

#[test]
fn per_sender_fifo_preserved() {
    // Two senders with different message sizes interleave at the
    // receiver, but each sender's own stream arrives in send order.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(Some(net()), 4);
    s.add_node(Box::new(Blaster { id: 0, dst: 2, sizes: vec![200; 20] }));
    s.add_node(Box::new(Blaster { id: 1, dst: 2, sizes: (0..20).map(|i| 10 + i * 30).collect() }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 40, got: 0 }));
    s.run().unwrap();
    let trace = trace.lock().unwrap();
    assert_eq!(trace.len(), 40);
    for src in [0usize, 1] {
        let rounds: Vec<u64> = trace.iter().filter(|t| t.1 == src).map(|t| t.2).collect();
        assert_eq!(rounds, (0..20).collect::<Vec<u64>>(), "sender {src} out of order");
    }
    // Arrival times are globally nondecreasing (virtual-time pop order).
    for w in trace.windows(2) {
        assert!(w[0].0 <= w[1].0);
    }
}

#[test]
fn untimed_delivery_preserves_staging_order() {
    // network = None: everything at t = 0, ordered by staging sequence.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(None, 2);
    s.add_node(Box::new(Blaster { id: 0, dst: 1, sizes: vec![50; 30] }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 30, got: 0 }));
    s.run().unwrap();
    let trace = trace.lock().unwrap();
    let rounds: Vec<u64> = trace.iter().map(|t| t.2).collect();
    assert_eq!(rounds, (0..30).collect::<Vec<u64>>());
    assert!(trace.iter().all(|t| t.0 == 0.0));
}

/// Schedules a compute of `duration` whose *real* execution time is
/// `sleep_ms` (decoupled on purpose), then sends one message.
struct SleepyComputer {
    id: usize,
    dst: usize,
    duration: f64,
    sleep_ms: u64,
    sent: bool,
}

impl EventNode for SleepyComputer {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => {
                let ms = self.sleep_ms;
                ctx.start_compute(
                    self.duration,
                    Box::new(move || {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                        Ok(ComputeOutput::Value(ms as f64))
                    }),
                );
            }
            Wake::ComputeDone(_) => {
                ctx.send(env(self.id, self.dst, self.id as u64, 10));
                self.sent = true;
            }
            _ => {}
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.sent
    }
}

fn run_compute_race(workers: usize) -> Vec<(f64, usize, u64)> {
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(Some(net()), workers);
    let n = 6;
    for i in 0..n {
        // Virtual durations increase with id; REAL execution time
        // decreases with id, so wall-clock completion order is the
        // reverse of virtual order.
        s.add_node(Box::new(SleepyComputer {
            id: i,
            dst: n,
            duration: 0.05 * (i + 1) as f64,
            sleep_ms: 5 * (n - i) as u64,
            sent: false,
        }));
    }
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: n, got: 0 }));
    s.run().unwrap();
    let recorded = trace.lock().unwrap().clone();
    drop(s);
    recorded
}

#[test]
fn virtual_order_is_independent_of_real_completion_order() {
    let trace = run_compute_race(4);
    let srcs: Vec<usize> = trace.iter().map(|t| t.1).collect();
    // Virtual completion (and hence arrival) follows virtual durations,
    // not the reversed real sleep times.
    assert_eq!(srcs, vec![0, 1, 2, 3, 4, 5]);
}

#[test]
fn deterministic_across_worker_counts() {
    let a = run_compute_race(1);
    let b = run_compute_race(8);
    assert_eq!(a, b, "trace depends on worker count");
}

#[test]
fn compute_duration_advances_virtual_clock() {
    let trace = run_compute_race(2);
    // Node 0: compute 0.05s, then one 10-byte message.
    let w = wire_size(&env(0, 6, 0, 10)) as f64;
    let expect = 0.05 + w / 1000.0 + 0.01;
    assert!((trace[0].0 - expect).abs() < 1e-12, "{} vs {expect}", trace[0].0);
}
