//! Virtual-time scheduler semantics, artifact-free: deterministic
//! delivery ordering, per-sender FIFO preservation, uplink/latency
//! timestamp math, and independence from worker count / real execution
//! order. (Scheduler-vs-threads training equivalence lives in
//! `dl_integration.rs` — it needs compiled artifacts.)

use std::sync::{Arc, Mutex};

use decentralize_rs::communication::shaper::NetworkModel;
use decentralize_rs::communication::{wire_size, Envelope, MsgKind};
use decentralize_rs::scheduler::{ComputeOutput, EventNode, NodeCtx, Scheduler, Wake};

type Trace = Arc<Mutex<Vec<(f64, usize, u64)>>>;

fn env(src: usize, dst: usize, round: u64, len: usize) -> Envelope {
    Envelope {
        src,
        dst,
        round,
        kind: MsgKind::Model,
        sent_at_s: 0.0,
        trace: 0,
        payload: vec![7; len].into(),
    }
}

/// Sends a burst of messages (given payload sizes) to `dst` at t = 0.
struct Blaster {
    id: usize,
    dst: usize,
    sizes: Vec<usize>,
}

impl EventNode for Blaster {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Start = wake {
            for (r, &len) in self.sizes.iter().enumerate() {
                ctx.send(env(self.id, self.dst, r as u64, len));
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        true
    }
}

/// Records (arrival virtual time, src, round) for every message.
struct Collector {
    trace: Trace,
    expect: usize,
    got: usize,
}

impl EventNode for Collector {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        if let Wake::Message(env) = wake {
            self.trace.lock().unwrap().push((ctx.now_s, env.src, env.round));
            self.got += 1;
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.got >= self.expect
    }
}

fn net() -> NetworkModel {
    NetworkModel { latency_s: 0.01, bandwidth_bps: 1000.0 }
}

#[test]
fn delivery_times_follow_uplink_serialization() {
    // One sender, two messages: the second queues behind the first on
    // the sender's uplink; each pays one latency after its transfer.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(Some(net()), 1);
    s.add_node(Box::new(Blaster { id: 0, dst: 1, sizes: vec![100, 50] }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 2, got: 0 }));
    s.run().unwrap();
    let w0 = wire_size(&env(0, 1, 0, 100)) as f64;
    let w1 = wire_size(&env(0, 1, 1, 50)) as f64;
    let t0 = w0 / 1000.0 + 0.01;
    let t1 = (w0 + w1) / 1000.0 + 0.01;
    let trace = trace.lock().unwrap();
    assert_eq!(trace.len(), 2);
    assert!((trace[0].0 - t0).abs() < 1e-12, "{} vs {t0}", trace[0].0);
    assert!((trace[1].0 - t1).abs() < 1e-12, "{} vs {t1}", trace[1].0);
}

#[test]
fn per_sender_fifo_preserved() {
    // Two senders with different message sizes interleave at the
    // receiver, but each sender's own stream arrives in send order.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(Some(net()), 4);
    s.add_node(Box::new(Blaster { id: 0, dst: 2, sizes: vec![200; 20] }));
    s.add_node(Box::new(Blaster { id: 1, dst: 2, sizes: (0..20).map(|i| 10 + i * 30).collect() }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 40, got: 0 }));
    s.run().unwrap();
    let trace = trace.lock().unwrap();
    assert_eq!(trace.len(), 40);
    for src in [0usize, 1] {
        let rounds: Vec<u64> = trace.iter().filter(|t| t.1 == src).map(|t| t.2).collect();
        assert_eq!(rounds, (0..20).collect::<Vec<u64>>(), "sender {src} out of order");
    }
    // Arrival times are globally nondecreasing (virtual-time pop order).
    for w in trace.windows(2) {
        assert!(w[0].0 <= w[1].0);
    }
}

#[test]
fn untimed_delivery_preserves_staging_order() {
    // network = None: everything at t = 0, ordered by staging sequence.
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(None, 2);
    s.add_node(Box::new(Blaster { id: 0, dst: 1, sizes: vec![50; 30] }));
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: 30, got: 0 }));
    s.run().unwrap();
    let trace = trace.lock().unwrap();
    let rounds: Vec<u64> = trace.iter().map(|t| t.2).collect();
    assert_eq!(rounds, (0..30).collect::<Vec<u64>>());
    assert!(trace.iter().all(|t| t.0 == 0.0));
}

/// Schedules a compute of `duration` whose *real* execution time is
/// `sleep_ms` (decoupled on purpose), then sends one message.
struct SleepyComputer {
    id: usize,
    dst: usize,
    duration: f64,
    sleep_ms: u64,
    sent: bool,
}

impl EventNode for SleepyComputer {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => {
                let ms = self.sleep_ms;
                ctx.start_compute(
                    self.duration,
                    Box::new(move || {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                        Ok(ComputeOutput::Value(ms as f64))
                    }),
                );
            }
            Wake::ComputeDone(_) => {
                ctx.send(env(self.id, self.dst, self.id as u64, 10));
                self.sent = true;
            }
            _ => {}
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.sent
    }
}

fn run_compute_race(workers: usize) -> Vec<(f64, usize, u64)> {
    let trace: Trace = Arc::new(Mutex::new(Vec::new()));
    let mut s = Scheduler::new(Some(net()), workers);
    let n = 6;
    for i in 0..n {
        // Virtual durations increase with id; REAL execution time
        // decreases with id, so wall-clock completion order is the
        // reverse of virtual order.
        s.add_node(Box::new(SleepyComputer {
            id: i,
            dst: n,
            duration: 0.05 * (i + 1) as f64,
            sleep_ms: 5 * (n - i) as u64,
            sent: false,
        }));
    }
    s.add_node(Box::new(Collector { trace: Arc::clone(&trace), expect: n, got: 0 }));
    s.run().unwrap();
    let recorded = trace.lock().unwrap().clone();
    drop(s);
    recorded
}

#[test]
fn virtual_order_is_independent_of_real_completion_order() {
    let trace = run_compute_race(4);
    let srcs: Vec<usize> = trace.iter().map(|t| t.1).collect();
    // Virtual completion (and hence arrival) follows virtual durations,
    // not the reversed real sleep times.
    assert_eq!(srcs, vec![0, 1, 2, 3, 4, 5]);
}

#[test]
fn deterministic_across_worker_counts() {
    let a = run_compute_race(1);
    let b = run_compute_race(8);
    assert_eq!(a, b, "trace depends on worker count");
}

#[test]
fn compute_duration_advances_virtual_clock() {
    let trace = run_compute_race(2);
    // Node 0: compute 0.05s, then one 10-byte message.
    let w = wire_size(&env(0, 6, 0, 10)) as f64;
    let expect = 0.05 + w / 1000.0 + 0.01;
    assert!((trace[0].0 - expect).abs() < 1e-12, "{} vs {expect}", trace[0].0);
}

/// Full-surface ring fleet for the sharded-heap oracle: every round each
/// node arms a deadline timer, starts a compute job, and gossips with
/// both ring neighbors, advancing only once all three complete. Every
/// wake it observes lands in the shared trace as
/// `(virtual time, source id, round * 10 + kind)` with kind 0 = message,
/// 1 = compute completion, 2 = timer fire.
struct ShardedFleetNode {
    id: usize,
    fleet: usize,
    rounds: u64,
    round: u64,
    /// Buffered neighbor arrivals per round (a neighbor may run ahead).
    msgs: std::collections::HashMap<u64, usize>,
    compute_done: bool,
    timer_fired: bool,
    trace: Trace,
}

impl ShardedFleetNode {
    fn begin_round(&mut self, ctx: &mut NodeCtx) {
        let r = self.round;
        // Id- and round-skewed delays so the heads of different heap
        // shards carry genuinely distinct timestamps.
        ctx.set_timer(0.005 + (self.id % 7) as f64 * 1e-4);
        let duration = 0.01 + ((self.id + r as usize) % 5) as f64 * 0.003;
        ctx.start_compute(duration, Box::new(move || Ok(ComputeOutput::Value(r as f64))));
        for dst in [(self.id + 1) % self.fleet, (self.id + self.fleet - 1) % self.fleet] {
            ctx.send(env(self.id, dst, r, 20 + (self.id % 3) * 40));
        }
    }

    fn advance_if_ready(&mut self, ctx: &mut NodeCtx) {
        while self.round < self.rounds
            && self.msgs.get(&self.round).copied().unwrap_or(0) >= 2
            && self.compute_done
            && self.timer_fired
        {
            self.msgs.remove(&self.round);
            self.compute_done = false;
            self.timer_fired = false;
            self.round += 1;
            if self.round < self.rounds {
                self.begin_round(ctx);
            }
        }
    }
}

impl EventNode for ShardedFleetNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> anyhow::Result<()> {
        match wake {
            Wake::Start => self.begin_round(ctx),
            Wake::Message(env) => {
                self.trace.lock().unwrap().push((ctx.now_s, env.src, env.round * 10));
                if env.round >= self.round {
                    *self.msgs.entry(env.round).or_insert(0) += 1;
                }
                self.advance_if_ready(ctx);
            }
            Wake::ComputeDone(out) => {
                let r = match out {
                    ComputeOutput::Value(v) => v as u64,
                    _ => unreachable!("fleet node only produces Value outputs"),
                };
                self.trace.lock().unwrap().push((ctx.now_s, self.id, r * 10 + 1));
                self.compute_done = true;
                self.advance_if_ready(ctx);
            }
            Wake::Timer(_) => {
                self.trace.lock().unwrap().push((ctx.now_s, self.id, self.round * 10 + 2));
                self.timer_fired = true;
                self.advance_if_ready(ctx);
            }
        }
        Ok(())
    }
    fn done(&self) -> bool {
        self.round >= self.rounds
    }
}

#[test]
fn sharded_heaps_bit_identical_across_worker_counts() {
    // The per-worker heap shards must merge into exactly the global
    // (at, seq) order a single heap would produce: the complete wake
    // trace — message arrivals, compute completions, and timer fires,
    // with their virtual timestamps — is the oracle, compared bitwise
    // across workers 1 / 4 / 8 (different worker counts mean different
    // shard counts AND different real execution interleavings).
    let run = |workers: usize| -> Vec<(u64, usize, u64)> {
        let trace: Trace = Arc::new(Mutex::new(Vec::new()));
        let fleet = 24;
        let mut s = Scheduler::new(Some(net()), workers);
        for id in 0..fleet {
            s.add_node(Box::new(ShardedFleetNode {
                id,
                fleet,
                rounds: 3,
                round: 0,
                msgs: std::collections::HashMap::new(),
                compute_done: false,
                timer_fired: false,
                trace: Arc::clone(&trace),
            }));
        }
        s.run().unwrap();
        let recorded = trace.lock().unwrap().clone();
        drop(s);
        recorded.iter().map(|&(at, src, tag)| (at.to_bits(), src, tag)).collect()
    };
    let a = run(1);
    let b = run(4);
    let c = run(8);
    // 3 rounds x 24 nodes x (2 messages + 1 compute + 1 timer).
    assert_eq!(a.len(), 3 * 24 * 4);
    assert_eq!(a, b, "trace differs between 1 and 4 workers");
    assert_eq!(a, c, "trace differs between 1 and 8 workers");
}
