//! Shared plumbing for the figure-regeneration examples.

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::{run_experiment, RunResult};
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::util::args::Args;

pub const FLAGS: &[&str] = &["save", "help"];

/// Base config tuned so topology/sharing effects are visible on the
/// synthetic task (calibrated in EXPERIMENTS.md): harder noise, one local
/// step, modest lr.
pub fn base_config(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = name.into();
    cfg.noise = 2.2;
    cfg.lr = 0.03;
    cfg.local_steps = 1;
    cfg.eval_every = 5;
    cfg
}

/// Apply the common CLI overrides every figure harness accepts.
pub fn apply_common(cfg: &mut ExperimentConfig, args: &Args) -> anyhow::Result<()> {
    cfg.nodes = args.get_parse("nodes", cfg.nodes)?;
    cfg.rounds = args.get_parse("rounds", cfg.rounds)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.train_total = args.get_parse("train-total", cfg.train_total)?;
    cfg.eval_every = args.get_parse("eval-every", cfg.eval_every)?;
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.into();
    }
    Ok(())
}

/// Run one experiment variant, echoing progress.
pub fn run(
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    save: bool,
) -> anyhow::Result<RunResult> {
    eprintln!(
        ">> {} (nodes={} rounds={} topology={}{} sharing={}{})",
        cfg.name,
        cfg.nodes,
        cfg.rounds,
        cfg.topology,
        if cfg.dynamic { " dynamic" } else { "" },
        cfg.sharing,
        if cfg.secure { " secure" } else { "" },
    );
    let result = run_experiment(cfg, engine)?;
    eprintln!(
        "   acc {:.4}  bytes/node {:.0}  emu {:.2}s  wall {:.1}s",
        result.final_accuracy(),
        result.final_bytes_per_node(),
        result.final_emu_time(),
        result.wall_s
    );
    if save {
        let dir = result.save()?;
        eprintln!("   saved to {}", dir.display());
    }
    Ok(result)
}

/// Print a figure-style comparison table: one row per eval round, one
/// column group per variant.
#[allow(dead_code)]
pub fn print_comparison(title: &str, columns: &[(&str, &RunResult)]) {
    println!("\n=== {title} ===");
    print!("{:>6}", "round");
    for (name, _) in columns {
        print!(
            " | {:>9} {:>12} {:>10}",
            format!("{name}.acc"),
            format!("{name}.bytes"),
            format!("{name}.emu_s")
        );
    }
    println!();
    let rows = columns.iter().map(|(_, r)| r.series.len()).min().unwrap_or(0);
    for i in 0..rows {
        print!("{:>6}", columns[0].1.series[i].round);
        for (_, r) in columns {
            let p = &r.series[i];
            print!(
                " | {:>9.4} {:>12.0} {:>10.3}",
                p.test_acc.mean, p.bytes_sent.mean, p.emu_time_s.mean
            );
        }
        println!();
    }
}
