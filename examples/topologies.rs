//! Figure 3 harness: 256-node DL across ring / 5-regular / fully-connected
//! / dynamic 5-regular topologies (paper §3.2).
//!
//! Prints the three panels as columns: (a) accuracy vs rounds,
//! (b) accuracy vs emulated wall-clock, (c) accuracy vs cumulative bytes
//! per node, plus the headline ratios (fully-connected round-time ×, and
//! the dynamic-vs-full communication saving).
//!
//! Paper scale: `--nodes 256 --rounds 500`. Default here is scaled down
//! for a single core; the shapes — full > regular > ring per round,
//! full ≈ 3× slower per round, dynamic ≈ full accuracy at a fraction of
//! the bytes — hold at both scales (see EXPERIMENTS.md).
//!
//! Run: `cargo run --release --example topologies -- [--nodes N --rounds R --save]`

mod common;

use common::{apply_common, base_config, print_comparison, run, FLAGS};
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(FLAGS)?;
    let save = args.flag("save");

    let mut base = base_config("fig3");
    base.nodes = 24;
    base.rounds = 30;
    base.train_total = 1536;
    apply_common(&mut base, &args)?;

    let engine = EngineHandle::start(&base.artifacts_dir, &[&base.model])?;

    let mut ring = base.clone();
    ring.name = "fig3_ring".into();
    ring.topology = "ring".into();

    let mut regular = base.clone();
    regular.name = "fig3_regular5".into();
    regular.topology = "regular:5".into();

    let mut full = base.clone();
    full.name = "fig3_full".into();
    full.topology = "full".into();

    let mut dynamic = base.clone();
    dynamic.name = "fig3_dynamic5".into();
    dynamic.topology = "regular:5".into();
    dynamic.dynamic = true;

    let r_ring = run(&ring, &engine, save)?;
    let r_reg = run(&regular, &engine, save)?;
    let r_full = run(&full, &engine, save)?;
    let r_dyn = run(&dynamic, &engine, save)?;

    print_comparison(
        "Figure 3: topology comparison (acc / cumulative bytes / emulated time)",
        &[
            ("ring", &r_ring),
            ("reg5", &r_reg),
            ("full", &r_full),
            ("dyn5", &r_dyn),
        ],
    );

    // Headline claims.
    let t_ratio = r_full.final_emu_time() / r_reg.final_emu_time();
    let comm_saving = r_full.final_bytes_per_node() / r_dyn.final_bytes_per_node();
    println!("\nheadline ratios:");
    println!(
        "  fully-connected round time vs 5-regular : {t_ratio:.1}x (paper: ~3x at 256 nodes)"
    );
    println!(
        "  full vs dynamic-5 communication         : {comm_saving:.1}x (paper: 51x at 256 nodes)"
    );
    println!(
        "  accuracy: full {:.4} vs dynamic-5 {:.4} (paper: nearly identical given time)",
        r_full.final_accuracy(),
        r_dyn.final_accuracy()
    );
    println!(
        "  per-round ordering: full {:.4} > regular {:.4} > ring {:.4}",
        r_full.final_accuracy(),
        r_reg.final_accuracy(),
        r_ring.final_accuracy()
    );
    engine.shutdown();
    Ok(())
}
