//! Figure 6 harness: scalability study — N vs 4N nodes over the SAME
//! total dataset (so 4N nodes get 4x fewer samples each), degree 5 vs
//! degree 9 (paper §3.5; 256 vs 1024 nodes in the paper).
//!
//! Expected shape: 5-regular at N and at 4N reach nearly the same
//! accuracy (degree matters more than per-node sample count), and degree
//! 9 beats degree 5 at 4N by several points.
//!
//! Run: `cargo run --release --example scalability -- [--nodes N --rounds R]`
//! (`--nodes` sets the SMALL setting; the large one is 4x that.)

mod common;

use common::{apply_common, base_config, print_comparison, run, FLAGS};
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(FLAGS)?;
    let save = args.flag("save");

    let mut base = base_config("fig6");
    base.nodes = 16;
    base.rounds = 40;
    base.train_total = 2048; // FIXED total; per-node share shrinks with N
    apply_common(&mut base, &args)?;
    let small_n = base.nodes;
    let large_n = small_n * 4;

    let engine = EngineHandle::start(&base.artifacts_dir, &[&base.model])?;

    let mut small5 = base.clone();
    small5.name = format!("fig6_{small_n}n_5reg");
    small5.topology = "regular:5".into();
    small5.nodes = small_n;

    let mut large5 = base.clone();
    large5.name = format!("fig6_{large_n}n_5reg");
    large5.topology = "regular:5".into();
    large5.nodes = large_n;

    let mut large9 = base.clone();
    large9.name = format!("fig6_{large_n}n_9reg");
    large9.topology = "regular:9".into();
    large9.nodes = large_n;

    let r_s5 = run(&small5, &engine, save)?;
    let r_l5 = run(&large5, &engine, save)?;
    let r_l9 = run(&large9, &engine, save)?;

    print_comparison(
        &format!("Figure 6: scalability {small_n} vs {large_n} nodes, degree 5 vs 9"),
        &[
            (&format!("{small_n}n/5r"), &r_s5),
            (&format!("{large_n}n/5r"), &r_l5),
            (&format!("{large_n}n/9r"), &r_l9),
        ],
    );

    println!("\nheadline:");
    println!(
        "  5-regular: {small_n} nodes {:.4} vs {large_n} nodes {:.4} (paper: ~equal despite 4x fewer samples/node)",
        r_s5.final_accuracy(),
        r_l5.final_accuracy()
    );
    println!(
        "  at {large_n} nodes: degree 9 {:.4} vs degree 5 {:.4} (+{:.1} points; paper: +5.8)",
        r_l9.final_accuracy(),
        r_l5.final_accuracy(),
        (r_l9.final_accuracy() - r_l5.final_accuracy()) * 100.0
    );
    engine.shutdown();
    Ok(())
}
