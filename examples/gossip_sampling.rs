//! Decentralized peer sampling demo (paper future work, implemented in
//! `node::GossipView`): build per-round dynamic neighbor sets WITHOUT the
//! centralized peer sampler, purely from the gossip peer-sampling
//! service, and verify the service's quality — view spread, indegree
//! balance, and the effective topology's spectral gap vs a true random
//! d-regular graph.
//!
//! Run: `cargo run --release --example gossip_sampling -- [--nodes N]`

use decentralize_rs::graph::{self, Graph};
use decentralize_rs::node::{gossip_simulate, GossipView};
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["help"])?;
    let n: usize = args.get_parse("nodes", 64usize)?;
    let cap: usize = args.get_parse("capacity", 10usize)?;
    let d: usize = args.get_parse("degree", 5usize)?;
    let rounds: usize = args.get_parse("rounds", 50usize)?;

    // Bootstrap every node's view from a ring, then gossip.
    let mut views: Vec<GossipView> = (0..n)
        .map(|i| GossipView::new(i, cap, &[(i + 1) % n, (i + n - 1) % n], 77 + i as u64))
        .collect();
    gossip_simulate(&mut views, rounds);

    // Indegree balance of the converged views.
    let mut indeg = vec![0usize; n];
    for v in &views {
        for dsc in v.view() {
            indeg[dsc.peer] += 1;
        }
    }
    let (min_d, max_d) = (
        indeg.iter().min().unwrap(),
        indeg.iter().max().unwrap(),
    );
    println!("gossip peer sampling on {n} nodes (capacity {cap}, {rounds} rounds)");
    println!("  indegree min/max        : {min_d} / {max_d} (uniform target {cap})");

    // Build one round's DL topology from gossip samples and compare its
    // mixing quality to a centrally-sampled random regular graph.
    let mut g = Graph::empty(n);
    for v in views.iter_mut() {
        for peer in v.sample_neighbors(d) {
            g.add_edge(v.node, peer);
        }
    }
    let gap_gossip = graph::spectral_gap(&g, 200);
    let mut rng = Xoshiro256pp::new(1);
    let reference = graph::random_regular(n, d, &mut rng).expect("reference d-regular sample");
    let gap_ref = graph::spectral_gap(&reference, 200);
    let (dmin, dmean, dmax) = graph::degree_stats(&g);
    println!("  gossip topology degree  : min {dmin} / mean {dmean:.1} / max {dmax}");
    println!("  connected               : {}", graph::is_connected(&g));
    println!("  spectral gap            : {gap_gossip:.4} (central d-regular: {gap_ref:.4})");
    println!(
        "  verdict                 : {}",
        if gap_gossip > gap_ref * 0.5 && graph::is_connected(&g) {
            "gossip-built topologies mix comparably — viable sampler replacement"
        } else {
            "needs more gossip rounds or larger views"
        }
    );
    Ok(())
}
