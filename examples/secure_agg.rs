//! Figure 5 harness: secure aggregation vs plain D-PSGD on both datasets
//! (paper §3.4; 48 nodes, CIFAR-10 + CelebA in the paper).
//!
//! Expected shape: secure aggregation pays a small communication overhead
//! (pairwise seeds + key agreement, ~3%) and a small accuracy cost from
//! f32 mask-cancellation residue, larger on the harder dataset.
//!
//! Run: `cargo run --release --example secure_agg -- [--nodes N --rounds R]`

mod common;

use common::{apply_common, base_config, print_comparison, run, FLAGS};
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(FLAGS)?;
    let save = args.flag("save");

    let mut base = base_config("fig5");
    base.nodes = 16;
    base.rounds = 40;
    base.train_total = 1024;
    base.topology = "regular:5".into();
    apply_common(&mut base, &args)?;

    let engine = EngineHandle::start(&base.artifacts_dir, &["mlp", "celeba"])?;

    // CIFAR10-S panel.
    let mut c_plain = base.clone();
    c_plain.name = "fig5_cifar_dpsgd".into();
    let mut c_secure = base.clone();
    c_secure.name = "fig5_cifar_secure".into();
    c_secure.secure = true;

    // CelebA-S panel.
    let mut a_plain = base.clone();
    a_plain.name = "fig5_celeba_dpsgd".into();
    a_plain.model = "celeba".into();
    a_plain.dataset = "celebas".into();
    let mut a_secure = a_plain.clone();
    a_secure.name = "fig5_celeba_secure".into();
    a_secure.secure = true;

    let r_cp = run(&c_plain, &engine, save)?;
    let r_cs = run(&c_secure, &engine, save)?;
    let r_ap = run(&a_plain, &engine, save)?;
    let r_as = run(&a_secure, &engine, save)?;

    print_comparison(
        "Figure 5 (CIFAR10-S): secure aggregation vs D-PSGD",
        &[("dpsgd", &r_cp), ("secure", &r_cs)],
    );
    print_comparison(
        "Figure 5 (CelebA-S): secure aggregation vs D-PSGD",
        &[("dpsgd", &r_ap), ("secure", &r_as)],
    );

    let overhead_c =
        (r_cs.final_bytes_per_node() / r_cp.final_bytes_per_node() - 1.0) * 100.0;
    let overhead_a =
        (r_as.final_bytes_per_node() / r_ap.final_bytes_per_node() - 1.0) * 100.0;
    println!("\nheadline:");
    println!(
        "  CIFAR10-S: acc {:.4} -> {:.4} (Δ {:+.3}), bytes +{overhead_c:.1}% (paper: ~-3% acc, ~+3% bytes)",
        r_cp.final_accuracy(),
        r_cs.final_accuracy(),
        r_cs.final_accuracy() - r_cp.final_accuracy()
    );
    println!(
        "  CelebA-S:  acc {:.4} -> {:.4} (Δ {:+.3}), bytes +{overhead_a:.1}% (paper: comparable acc)",
        r_ap.final_accuracy(),
        r_as.final_accuracy(),
        r_as.final_accuracy() - r_ap.final_accuracy()
    );

    // Precision-loss ablation: the paper's ~3% CIFAR-10 accuracy drop is
    // f32 mask-cancellation residue; it grows with the mask amplitude.
    println!("\nmask-amplitude ablation (CIFAR10-S, residue -> accuracy):");
    for scale in [4.0f32, 1e3, 1e5] {
        let mut c = c_secure.clone();
        c.name = format!("fig5_cifar_secure_m{scale:.0}");
        c.mask_scale = scale;
        let r = run(&c, &engine, false)?;
        println!(
            "  mask_scale {scale:>8.0}: acc {:.4} (Δ {:+.4} vs plain)",
            r.final_accuracy(),
            r.final_accuracy() - r_cp.final_accuracy()
        );
    }
    engine.shutdown();
    Ok(())
}
