//! End-to-end validation driver (DESIGN.md requirement): a full DL
//! training run on a realistic small workload, proving all three layers
//! compose — Rust coordination + transport, PJRT execution of the JAX
//! model, and the Pallas dense kernels inside it.
//!
//! 16 nodes, 5-regular static topology, 2-shard non-IID CIFAR10-S,
//! 200 communication rounds by default. Logs the loss/accuracy curve,
//! saves per-node JSONL logs under results/e2e_train/, and prints the
//! summary recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_train -- [--rounds 200 --nodes 16]`

mod common;

use common::{apply_common, base_config, run, FLAGS};
use decentralize_rs::metrics::render_series;
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(FLAGS)?;

    let mut cfg = base_config("e2e_train");
    cfg.nodes = 16;
    cfg.rounds = 200;
    cfg.eval_every = 10;
    cfg.train_total = 2048;
    cfg.test_total = 512;
    cfg.topology = "regular:5".into();
    apply_common(&mut cfg, &args)?;

    let engine = EngineHandle::start(&cfg.artifacts_dir, &[&cfg.model])?;
    let meta = engine.manifest().model(&cfg.model)?;
    eprintln!(
        "e2e: model={} P={} train_batch={} | {} nodes x {} rounds, {} per node",
        cfg.model,
        meta.param_count,
        meta.train_batch,
        cfg.nodes,
        cfg.rounds,
        cfg.train_total / cfg.nodes
    );

    let result = run(&cfg, &engine, true)?;

    print!("{}", render_series("e2e_train (loss/accuracy curve)", &result.series));
    let first = result.series.first().unwrap();
    let last = result.series.last().unwrap();
    println!("\nE2E SUMMARY");
    println!(
        "  train loss  {:.4} -> {:.4}",
        first.train_loss.mean, last.train_loss.mean
    );
    println!(
        "  test acc    {:.4} -> {:.4} (±{:.4} across nodes)",
        first.test_acc.mean, last.test_acc.mean, last.test_acc.ci95
    );
    println!(
        "  bytes/node  {:.2e}   emu {:.1}s   wall {:.1}s",
        last.bytes_sent.mean, last.emu_time_s.mean, result.wall_s
    );
    println!("  logs: results/e2e_train/node_*.jsonl");
    anyhow::ensure!(
        last.test_acc.mean > 0.5,
        "end-to-end run failed to learn (acc {:.3})",
        last.test_acc.mean
    );
    println!("  STATUS: PASS (all three layers compose, model learns)");
    engine.shutdown();
    Ok(())
}
