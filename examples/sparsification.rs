//! Figure 4 harness: sparsification vs full sharing at a 10% budget on a
//! 5-regular topology with 2-shard non-IID data (paper §3.3).
//!
//! Variants: full sharing (baseline), random subsampling, Choco-SGD, plus
//! TopK as the extra reference implementation the framework ships.
//! Expected shape: under non-IID data at scale, the sparsifiers lose
//! accuracy at the same round count AND need more bytes to reach a fixed
//! accuracy than full sharing — the paper's (counter-intuitive) headline.
//!
//! Run: `cargo run --release --example sparsification -- [--nodes N --rounds R --budget 0.1]`

mod common;

use common::{apply_common, base_config, print_comparison, run, FLAGS};
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(FLAGS)?;
    let save = args.flag("save");
    let budget: f64 = args.get_parse("budget", 0.1f64)?;

    let mut base = base_config("fig4");
    base.nodes = 24;
    base.rounds = 40;
    base.train_total = 1536;
    base.topology = "regular:5".into();
    apply_common(&mut base, &args)?;

    let engine = EngineHandle::start(&base.artifacts_dir, &[&base.model])?;

    let mut full = base.clone();
    full.name = "fig4_full".into();

    let mut random = base.clone();
    random.name = "fig4_random".into();
    random.sharing = format!("subsample:{budget}");

    let mut choco = base.clone();
    choco.name = "fig4_choco".into();
    choco.sharing = format!("choco:{budget}:0.6");

    let mut topk = base.clone();
    topk.name = "fig4_topk".into();
    topk.sharing = format!("topk:{budget}");

    let r_full = run(&full, &engine, save)?;
    let r_rand = run(&random, &engine, save)?;
    let r_choco = run(&choco, &engine, save)?;
    let r_topk = run(&topk, &engine, save)?;

    print_comparison(
        &format!("Figure 4: sparsification at {:.0}% budget vs full sharing", budget * 100.0),
        &[
            ("full", &r_full),
            ("rand", &r_rand),
            ("choco", &r_choco),
            ("topk", &r_topk),
        ],
    );

    println!("\nheadline:");
    println!(
        "  final acc: full {:.4} | random {:.4} | choco {:.4} | topk {:.4}",
        r_full.final_accuracy(),
        r_rand.final_accuracy(),
        r_choco.final_accuracy(),
        r_topk.final_accuracy()
    );
    println!(
        "  bytes/node: full {:.2e} | random {:.2e} | choco {:.2e} | topk {:.2e}",
        r_full.final_bytes_per_node(),
        r_rand.final_bytes_per_node(),
        r_choco.final_bytes_per_node(),
        r_topk.final_bytes_per_node()
    );
    // Bytes needed to reach the best sparsifier's final accuracy.
    let target = r_rand
        .final_accuracy()
        .max(r_choco.final_accuracy())
        .max(r_topk.final_accuracy());
    if let Some(p) = r_full
        .series
        .iter()
        .find(|p| p.test_acc.mean >= target)
    {
        println!(
            "  full sharing reaches the sparsifiers' final accuracy ({target:.4}) with {:.2e} bytes/node — fewer than any sparsifier (paper's conclusion)",
            p.bytes_sent.mean
        );
    }
    engine.shutdown();
    Ok(())
}
