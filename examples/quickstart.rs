//! Quickstart: the paper's Fig 2 "simple DL node in a few lines",
//! DecentralizeRs edition. Eight nodes train collaboratively on a
//! 3-regular graph; we print the aggregated accuracy curve.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::run_experiment;
use decentralize_rs::metrics::render_series;
use decentralize_rs::runtime::EngineHandle;

fn main() -> anyhow::Result<()> {
    // 1. Describe the experiment (every field has a sane default).
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.nodes = 8;
    cfg.rounds = 12;
    cfg.eval_every = 3;
    cfg.topology = "regular:3".into(); // swap for "ring", "full", ...
    cfg.sharing = "full".into(); //        ... or "topk:0.1", "choco:0.1:0.5"
    cfg.train_total = 768;
    cfg.test_total = 256;

    // 2. Start the PJRT engine on the AOT artifacts (L2/L1 output).
    let engine = EngineHandle::start(&cfg.artifacts_dir, &[&cfg.model])?;

    // 3. Run: the coordinator builds the dataset partition, topology and
    //    one thread per node, then drives the D-PSGD rounds.
    let result = run_experiment(&cfg, &engine)?;

    // 4. Inspect the aggregated series (mean ± 95% CI across nodes).
    print!("{}", render_series("quickstart", &result.series));
    println!(
        "final accuracy {:.3} after {} rounds ({} bytes/node)",
        result.final_accuracy(),
        cfg.rounds,
        result.final_bytes_per_node() as u64
    );
    engine.shutdown();
    Ok(())
}
